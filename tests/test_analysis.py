"""Protocol linter (repro.analysis): per-checker fixtures — violating
and clean — allow-comment semantics, CLI exit codes, and the tier-1
gate: zero findings on the repo's own src/."""
import os
import subprocess
import sys
import textwrap

from repro.analysis import list_allows, run_analysis
from repro.analysis.atomic import check_atomic_writes
from repro.analysis.concurrency import check_concurrency
from repro.analysis.imports import check_worker_purity
from repro.analysis.tmpvis import check_tmp_invisible
from repro.analysis.trace import check_trace_purity

REPO_SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def make_tree(tmp_path, files):
    """Write ``{relpath: source}`` fixtures; return the tree root str."""
    for rel, src in files.items():
        path = tmp_path / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(textwrap.dedent(src))
    return str(tmp_path)


def rules(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# atomic-write
# ---------------------------------------------------------------------------

class TestAtomicWrite:
    def test_raw_writers_in_protocol_module_flagged(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/mq.py": """
            import json
            import os
            import pickle
            import numpy as np

            def publish(path, obj, arr, fd):
                with open(path, "w") as f:
                    json.dump(obj, f)
                pickle.dump(obj, open(path, "wb"))
                np.savez(path, arr=arr)
                os.fdopen(fd, mode="w").write("x")
            """})
        findings = run_analysis([root], [check_atomic_writes])
        # open "w", json.dump, pickle.dump AND its nested open "wb",
        # np.savez, os.fdopen "w" — six raw publication sites
        assert rules(findings) == ["atomic-write"] * 6
        assert all("fsatomic" in f.message for f in findings)

    def test_aliased_writer_resolved(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/batchq.py": """
            import numpy as xp
            from json import dump as jd

            def publish(path, obj, arr):
                xp.savez_compressed(path, arr=arr)
                jd(obj, open(path))
            """})
        assert rules(run_analysis([root], [check_atomic_writes])) == \
            ["atomic-write"] * 2

    def test_reads_and_nonprotocol_modules_clean(self, tmp_path):
        root = make_tree(tmp_path, {
            "repro/runtime/mq.py": """
                import json

                def load(path):
                    with open(path) as f:        # default mode: read
                        return json.load(f)

                def load_b(path):
                    with open(path, "rb") as f:  # read mode
                        return f.read()
                """,
            # same raw writes OUTSIDE the protocol modules: not flagged
            "repro/train/ckpt.py": """
                import json

                def save(path, obj):
                    with open(path, "w") as f:
                        json.dump(obj, f)
                """})
        assert run_analysis([root], [check_atomic_writes]) == []


# ---------------------------------------------------------------------------
# allow-comment escape hatch
# ---------------------------------------------------------------------------

class TestAllowComment:
    def _root(self, tmp_path, comment):
        return make_tree(tmp_path, {"repro/runtime/mq.py": f"""
            def lease(path):
                {comment}
                with open(path, "w") as f:
                    f.write("hb")
            """})

    def test_allow_with_reason_suppresses(self, tmp_path):
        root = self._root(tmp_path,
                          "# lint: allow[atomic-write] mtime-only lease")
        assert run_analysis([root], [check_atomic_writes]) == []

    def test_allow_without_reason_does_not_suppress(self, tmp_path):
        root = self._root(tmp_path, "# lint: allow[atomic-write]")
        assert rules(run_analysis([root], [check_atomic_writes])) == \
            ["atomic-write"]

    def test_allow_for_other_rule_does_not_suppress(self, tmp_path):
        root = self._root(tmp_path, "# lint: allow[bare-except] nope")
        assert rules(run_analysis([root], [check_atomic_writes])) == \
            ["atomic-write"]

    def test_trailing_allow_on_flagged_line(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/mq.py": """
            def lease(path):
                f = open(path, "w")  # lint: allow[atomic-write] heartbeat
                f.write("hb")
            """})
        assert run_analysis([root], [check_atomic_writes]) == []

    def test_reason_may_span_comment_block(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/mq.py": """
            def lease(path):
                # lint: allow[atomic-write] lease is mtime-only liveness:
                # pollers read getmtime, never the body, so a torn write
                # is harmless and a rename would race os.utime
                with open(path, "w") as f:
                    f.write("hb")
            """})
        assert run_analysis([root], [check_atomic_writes]) == []


# ---------------------------------------------------------------------------
# worker-purity
# ---------------------------------------------------------------------------

class TestWorkerPurity:
    def test_transitive_module_scope_jax_flagged_with_chain(self, tmp_path):
        root = make_tree(tmp_path, {
            "repro/runtime/mq.py": "from repro.core import helper\n",
            "repro/core/__init__.py": "",
            "repro/core/helper.py": "import jax\n"})
        findings = run_analysis([root], [check_worker_purity])
        assert rules(findings) == ["worker-purity"]
        assert "repro.runtime.mq" in findings[0].message
        assert "repro.core.helper -> jax" in findings[0].message
        assert findings[0].path.endswith("helper.py")

    def test_function_scoped_jax_clean(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/batchq.py": """
            def bridge(x):
                import jax
                return jax.numpy.asarray(x)
            """})
        assert run_analysis([root], [check_worker_purity]) == []

    def test_eager_reexport_in_parent_package_flagged(self, tmp_path):
        # importing repro.runtime.mq executes repro/runtime/__init__.py:
        # an eager heavy re-export there poisons every worker
        root = make_tree(tmp_path, {
            "repro/runtime/__init__.py": "import jax\n",
            "repro/runtime/mq.py": ""})
        findings = run_analysis([root], [check_worker_purity])
        assert rules(findings) == ["worker-purity"]
        assert findings[0].path.endswith("__init__.py")

    def test_heavy_import_outside_closure_clean(self, tmp_path):
        root = make_tree(tmp_path, {
            "repro/runtime/mq.py": "import numpy\n",
            "repro/core/engine.py": "import jax\n"})
        assert run_analysis([root], [check_worker_purity]) == []


# ---------------------------------------------------------------------------
# trace-purity
# ---------------------------------------------------------------------------

class TestTracePurity:
    def test_transitive_side_effect_under_jit_flagged(self, tmp_path):
        root = make_tree(tmp_path, {"repro/core/engine.py": """
            import time
            import jax

            def helper(x):
                return x + time.time()

            @jax.jit
            def step(x):
                return helper(x)
            """})
        findings = run_analysis([root], [check_trace_purity])
        assert rules(findings) == ["trace-purity"]
        assert "time.time" in findings[0].message

    def test_partial_jit_decorator_and_factory_roots(self, tmp_path):
        root = make_tree(tmp_path, {"repro/kernels/k.py": """
            import functools
            import random
            import subprocess
            import jax

            @functools.partial(jax.jit, static_argnames=("n",))
            def kernel(x, n):
                return x * random.random()

            def make_step(cfg):
                def step(x):
                    return subprocess.run(["true"])
                return step

            step = jax.jit(make_step(None))
            """})
        found = rules(run_analysis([root], [check_trace_purity]))
        assert found == ["trace-purity"] * 2

    def test_callback_bridge_first_arg_is_cut(self, tmp_path):
        root = make_tree(tmp_path, {"repro/core/engine.py": """
            import time
            import jax

            @jax.jit
            def step(x):
                # the callback body runs host-side: exempt
                return jax.pure_callback(lambda: time.time(), x)
            """})
        assert run_analysis([root], [check_trace_purity]) == []

    def test_side_effect_in_callback_operand_still_flagged(self, tmp_path):
        root = make_tree(tmp_path, {"repro/core/engine.py": """
            import time
            import jax

            @jax.jit
            def step(x):
                # only the FIRST arg is host-side; operands are traced
                return jax.pure_callback(lambda v: v, x * time.time())
            """})
        assert rules(run_analysis([root], [check_trace_purity])) == \
            ["trace-purity"]

    def test_host_side_code_unreached_from_jit_clean(self, tmp_path):
        root = make_tree(tmp_path, {"repro/core/engine.py": """
            import time
            import jax

            @jax.jit
            def step(x):
                return x + 1

            def host_loop(x):
                t0 = time.monotonic()
                return step(x), time.monotonic() - t0
            """})
        assert run_analysis([root], [check_trace_purity]) == []


# ---------------------------------------------------------------------------
# concurrency
# ---------------------------------------------------------------------------

class TestConcurrency:
    def test_bare_acquire_flagged(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/pool.py": """
            import threading
            lock = threading.Lock()

            def grab():
                lock.acquire()
            """})
        assert rules(run_analysis([root], [check_concurrency])) == \
            ["lock-acquire"]

    def test_blocking_call_under_lock_flagged(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/pool.py": """
            import subprocess
            import threading
            import time
            lock = threading.Lock()

            def tick(worker):
                with lock:
                    time.sleep(0.1)
                    subprocess.run(["true"])
                    worker.join()
            """})
        assert rules(run_analysis([root], [check_concurrency])) == \
            ["lock-blocking-call"] * 3

    def test_condition_wait_on_held_lock_exempt(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/pool.py": """
            import threading
            cond = threading.Condition()

            def drain(done):
                with cond:
                    cond.wait_for(done)   # releases while blocked: fine
                    cond.wait(1.0)
            """})
        assert run_analysis([root], [check_concurrency]) == []

    def test_str_join_under_lock_not_a_thread_join(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/pool.py": """
            import threading
            lock = threading.Lock()

            def fmt(parts):
                with lock:
                    return ",".join(parts)
            """})
        assert run_analysis([root], [check_concurrency]) == []

    def test_bare_except_only_flagged_inside_loops(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/pool.py": """
            def claim_loop():
                while True:
                    try:
                        return 1
                    except:
                        pass

            def single_shot():
                try:
                    return 1
                except:       # not a retry loop: tolerated
                    return 0
            """})
        findings = run_analysis([root], [check_concurrency])
        assert rules(findings) == ["bare-except"]
        assert findings[0].line == 6

    def test_os_path_join_under_lock_not_a_thread_join(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/pool.py": """
            import os
            import threading
            lock = threading.Lock()

            def path(a, b):
                with lock:
                    return os.path.join(a, b)
            """})
        assert run_analysis([root], [check_concurrency]) == []

    def test_thread_shared_mutation_flagged(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/pool.py": """
            import threading

            class Scaler:
                def __init__(self):
                    self.size = 1

                def start(self):
                    threading.Thread(target=self._run).start()

                def _run(self):
                    self.size += 1

                def shrink(self):
                    self.size -= 1
            """})
        findings = run_analysis([root], [check_concurrency])
        assert rules(findings) == ["thread-shared-mutation"]
        assert "self.size" in findings[0].message
        assert "common lock" in findings[0].message

    def test_thread_shared_transitive_closure_flagged(self, tmp_path):
        # the thread body reaches the mutation via self._tick()
        root = make_tree(tmp_path, {"repro/runtime/pool.py": """
            import threading

            class Scaler:
                def start(self):
                    threading.Thread(target=self._run).start()

                def _run(self):
                    self._tick()

                def _tick(self):
                    self.stats.update(ticks=1)

                def reset(self):
                    self.stats.clear()
            """})
        assert rules(run_analysis([root], [check_concurrency])) == \
            ["thread-shared-mutation"]

    def test_thread_shared_both_locked_clean(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/pool.py": """
            import threading

            class Scaler:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.size = 1

                def start(self):
                    threading.Thread(target=self._run).start()

                def _run(self):
                    with self._lock:
                        self.size += 1

                def shrink(self):
                    with self._lock:
                        self.size -= 1
            """})
        assert run_analysis([root], [check_concurrency]) == []

    def test_thread_shared_init_only_spawn_side_clean(self, tmp_path):
        # __init__ completes before any thread can hold the object
        root = make_tree(tmp_path, {"repro/runtime/pool.py": """
            import threading

            class Scaler:
                def __init__(self):
                    self.size = 1

                def start(self):
                    threading.Thread(target=self._run).start()

                def _run(self):
                    self.size += 1
            """})
        assert run_analysis([root], [check_concurrency]) == []

    def test_thread_shared_allow_suppresses(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/pool.py": """
            import threading

            class Scaler:
                def start(self):
                    threading.Thread(target=self._run).start()

                def _run(self):
                    # lint: allow[thread-shared-mutation] single writer
                    self.size += 1

                def shrink(self):
                    self.size -= 1
            """})
        assert run_analysis([root], [check_concurrency]) == []

    def test_thread_shared_nested_def_spawn_line_split(self, tmp_path):
        # mutations BEFORE the Thread exists cannot race its body; only
        # the spawner's tail after the construction line competes
        root = make_tree(tmp_path, {"repro/runtime/pool.py": """
            import threading

            class Harness:
                def run_before(self):
                    self.out = {}

                    def worker():
                        self.out.update(fit=1)

                    threading.Thread(target=worker).start()

                def run_after(self):
                    def worker():
                        self.res.update(fit=1)

                    threading.Thread(target=worker).start()
                    self.res.update(seed=0)
            """})
        findings = run_analysis([root], [check_concurrency])
        assert rules(findings) == ["thread-shared-mutation"]
        assert "self.res" in findings[0].message


# ---------------------------------------------------------------------------
# tmp-invisible
# ---------------------------------------------------------------------------

class TestTmpInvisible:
    def test_unfiltered_listing_in_protocol_module_flagged(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/mq.py": """
            import glob
            import os

            def drain(tasks_dir):
                for name in os.listdir(tasks_dir):   # raw entries!
                    os.remove(os.path.join(tasks_dir, name))

            def scan(tasks_dir):
                return glob.glob(tasks_dir + "/*")
            """})
        findings = run_analysis([root], [check_tmp_invisible])
        assert rules(findings) == ["tmp-invisible"] * 2
        assert all(".tmp" in f.message for f in findings)

    def test_suffix_filtered_listing_clean(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/mq.py": """
            import os

            def claimable(tasks_dir):
                return [n for n in os.listdir(tasks_dir)
                        if n.endswith(".npz")]
            """})
        assert run_analysis([root], [check_tmp_invisible]) == []

    def test_regex_and_parser_filters_accepted_as_evidence(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/batchq.py": """
            import os
            import re

            _RE = re.compile(r"chunk_(\\d+)\\.npz")

            def sweep(job_dir):
                return [n for n in os.listdir(job_dir)
                        if _RE.fullmatch(n)]

            def parse_task_name(name):
                return name

            def parsed(job_dir):
                return [parse_task_name(n) for n in os.listdir(job_dir)]
            """})
        assert run_analysis([root], [check_tmp_invisible]) == []

    def test_listing_outside_protocol_modules_clean(self, tmp_path):
        root = make_tree(tmp_path, {"repro/train/ckpt.py": """
            import os

            def all_ckpts(d):
                return os.listdir(d)
            """})
        assert run_analysis([root], [check_tmp_invisible]) == []

    def test_obs_exporter_listing_flagged(self, tmp_path):
        # the rule extends past the queue protocol into repro.obs: the
        # metric textfiles live in the SAME polled broker dirs, so a
        # scraper listing without a suffix filter would read an atomic
        # write's .tmp sibling
        root = make_tree(tmp_path, {"repro/obs/dashboard.py": """
            import os

            def scrape_all(metrics_dir):
                return [open(os.path.join(metrics_dir, n)).read()
                        for n in os.listdir(metrics_dir)]
            """})
        findings = run_analysis([root], [check_tmp_invisible])
        assert rules(findings) == ["tmp-invisible"]

    def test_obs_exporter_filtered_listing_clean(self, tmp_path):
        root = make_tree(tmp_path, {"repro/obs/dashboard.py": """
            import os

            def scrape_all(metrics_dir):
                return [open(os.path.join(metrics_dir, n)).read()
                        for n in os.listdir(metrics_dir)
                        if n.endswith(".prom")]
            """})
        assert run_analysis([root], [check_tmp_invisible]) == []

    def test_lease_body_read_flagged_metadata_poll_clean(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/mq.py": """
            import os

            def beat_bad(lease_path):
                with open(lease_path) as f:          # body read!
                    return float(f.read())

            def beat_good(lease_path):
                # metadata-only: the mtime IS the heartbeat
                return os.path.getmtime(lease_path)

            def load_task(npz_path):
                with open(npz_path, "rb") as f:      # not a lease
                    return f.read()
            """})
        findings = run_analysis([root], [check_tmp_invisible])
        assert rules(findings) == ["tmp-invisible"]
        assert "metadata-only" in findings[0].message

    def test_allow_comment_suppresses(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/mq.py": """
            import os

            def raw(d):
                # lint: allow[tmp-invisible] debug dump of ALL entries
                return os.listdir(d)
            """})
        assert run_analysis([root], [check_tmp_invisible]) == []


# ---------------------------------------------------------------------------
# allow inventory (--list-allows)
# ---------------------------------------------------------------------------

class TestListAllows:
    def test_live_and_stale_allows_inventoried(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/mq.py": """
            def lease(path):
                # lint: allow[atomic-write] mtime-only heartbeat
                with open(path, "w") as f:
                    f.write("hb")

            def read(path):
                # lint: allow[atomic-write] outlived its write
                with open(path) as f:
                    return f.read()
            """})
        allows = list_allows([root], [check_atomic_writes])
        assert [(a.rule, a.stale) for a in allows] == [
            ("atomic-write", False), ("atomic-write", True)]
        assert allows[0].reason == "mtime-only heartbeat"
        assert "STALE" in str(allows[1]) and "STALE" not in str(allows[0])

    def test_docstring_mention_is_not_an_allow(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/mq.py": '''
            """Exceptions carry ``# lint: allow[atomic-write] reason``."""
            x = 1
            '''})
        assert list_allows([root], [check_atomic_writes]) == []

    def test_cli_prints_inventory_and_stale_warning(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/mq.py": """
            def read(path):
                # lint: allow[atomic-write] nothing here triggers it
                with open(path) as f:
                    return f.read()
            """})
        proc = _run_cli(root, "--list-allows")
        assert proc.returncode == 0          # stale allows are advisory
        line = proc.stdout.strip().splitlines()[0]
        assert "atomic-write" in line and "STALE" in line
        assert "warning: stale allow" in proc.stderr

    def test_repo_src_has_no_stale_allows(self):
        stale = [a for a in list_allows([REPO_SRC]) if a.stale]
        assert stale == [], "\n".join(str(a) for a in stale)


# ---------------------------------------------------------------------------
# CLI + tier-1 gate
# ---------------------------------------------------------------------------

def _run_cli(root, *extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(REPO_SRC) + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", root, *extra],
        capture_output=True, text=True, env=env)


class TestCli:
    def test_nonzero_exit_and_finding_format_on_violation(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/mq.py": """
            def publish(path):
                with open(path, "w") as f:
                    f.write("x")
            """})
        proc = _run_cli(root)
        assert proc.returncode == 1
        line = proc.stdout.strip().splitlines()[0]
        path, lineno, rule = line.split(" ", 2)[0].rsplit(":", 1) + \
            [line.split(" ", 2)[1]]
        assert path.endswith("mq.py")
        assert lineno.isdigit()
        assert rule == "atomic-write"

    def test_zero_exit_on_clean_tree(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/mq.py": "x = 1\n"})
        proc = _run_cli(root)
        assert proc.returncode == 0
        assert proc.stdout.strip() == ""

    def test_syntax_error_is_a_finding_not_a_crash(self, tmp_path):
        root = make_tree(tmp_path, {"repro/runtime/mq.py": "def broken(:\n"})
        findings = run_analysis([root])
        assert rules(findings) == ["parse-error"]


def test_repo_src_has_zero_findings():
    """Tier-1 gate: the protocol invariants hold on the repo itself.
    Every deliberate exception must carry `# lint: allow[rule] reason`;
    anything else showing up here is a real protocol regression."""
    findings = run_analysis([REPO_SRC])
    assert findings == [], "\n".join(str(f) for f in findings)
