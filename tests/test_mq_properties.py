"""Queue-invariant property/chaos tests for the message queue (run via
the hypothesis stub when the real package is absent): task-name parse
round-trips, single-winner claims under thread races, monotone delivery
bumps that never burn the retry budget, and first-result-wins under late
duplicates from superseded deliveries."""
import glob
import os
import tempfile
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.fitness import hostsim
from repro.runtime.fsatomic import atomic_savez
from repro.runtime.mq import (CLAIMED_DIR, LEASE_SUFFIX, RESULTS_DIR,
                              TASKS_DIR, LocalWorkerPool, QueueBackend,
                              claim_next, make_broker_dirs,
                              mq_result_path, parse_task_name,
                              sanitize_run_id, task_name)

SPEC = "repro.fitness.hostsim:sphere"


# ---------------------------------------------------------------------------
# task_name <-> parse_task_name round-trip
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(job=st.integers(0, 2_000_000), chunk=st.integers(0, 50_000),
       attempt=st.integers(0, 40), delivery=st.integers(0, 40),
       run=st.sampled_from(["a", "0", "run-a", "meta-ga-01", "x7-sweep"]))
def test_task_name_parse_roundtrip(job, chunk, attempt, delivery, run):
    """Any job/chunk/attempt/delivery — including values wider than the
    zero-padded field widths — survives the round trip, and near-miss
    names never parse."""
    name = task_name(run, job, chunk, attempt, delivery)
    assert parse_task_name(name) == (run, job, chunk, attempt, delivery)
    assert parse_task_name(name + ".tmp") is None
    assert parse_task_name(name[:-len(".npz")] + ".stop") is None
    assert parse_task_name("job_000001.npz") is None


def test_sanitize_run_id():
    assert sanitize_run_id("Meta GA/7") == "meta-ga-7"
    assert sanitize_run_id("run-a") == "run-a"
    with pytest.raises(ValueError):
        sanitize_run_id("///")


# ---------------------------------------------------------------------------
# claim exclusivity: N claimers racing on ONE task through a barrier
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(claimers=st.integers(2, 8))
def test_one_task_many_claimers_exactly_one_winner(claimers):
    """The atomic rename hands a single ready task to exactly one of N
    simultaneously released claimers; every loser sees None."""
    with tempfile.TemporaryDirectory() as mq:
        make_broker_dirs(mq)
        name = task_name("a", 0, 0, 0, 0)
        with open(os.path.join(mq, TASKS_DIR, name), "wb") as f:
            f.write(b"x")
        barrier = threading.Barrier(claimers)
        wins, lock = [], threading.Lock()

        def grab():
            barrier.wait()
            got = claim_next(mq)
            if got is not None:
                with lock:
                    wins.append(got)

        threads = [threading.Thread(target=grab) for _ in range(claimers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert wins == [name]
        assert os.listdir(os.path.join(mq, TASKS_DIR)) == []
        assert os.listdir(os.path.join(mq, CLAIMED_DIR)) == [name]


# ---------------------------------------------------------------------------
# chaos: repeated worker deaths bump the delivery suffix monotonically
# WITHOUT consuming the run_chunks_retry attempt budget
# ---------------------------------------------------------------------------

def test_stale_lease_requeue_bumps_delivery_monotonically(tmp_path):
    """Workers that claim chunk 0's d0 and d1 deliveries die without
    reporting; each death re-queues under the NEXT delivery suffix (d0 ->
    d1 -> d2) and the surviving worker completes d2 — zero retries, zero
    timeouts: liveness re-queues are free of the attempt budget."""
    pool = LocalWorkerPool(num_workers=3, mode="thread", lease_s=0.4,
                           poll_s=0.005,
                           hang_substrings=("c0000_t0_d0", "c0000_t0_d1"))
    with QueueBackend(fn_spec=SPEC, num_workers=2, run_id="chaos",
                      worker_pool=pool, lease_s=0.4, keep_jobs=4,
                      chunk_timeout_s=60, poll_interval_s=0.005,
                      mq_dir=str(tmp_path)) as backend:
        g = np.ones((8, 3), np.float32)
        out = backend._host_eval(g)
        np.testing.assert_allclose(out, hostsim.sphere(g), rtol=1e-6)
        assert backend.stats["lease_requeues"] >= 2
        assert backend.stats["retries"] == 0
        assert backend.stats["timeouts"] == 0
        # the chaos chunk's winning delivery reflects the monotone bumps
        (win,) = glob.glob(str(tmp_path / RESULTS_DIR
                               / "rchaos_j000000_c0000_*.result.npz"))
        parsed = parse_task_name(
            os.path.basename(win)[:-len(".result.npz")] + ".npz")
        assert parsed[3] == 0                    # attempt untouched
        assert parsed[4] >= 2                    # delivery bumped 0->1->2


# ---------------------------------------------------------------------------
# at-least-once: first result wins; a late duplicate from a superseded
# delivery is ignored (and swept)
# ---------------------------------------------------------------------------

def test_first_result_wins_over_late_superseded_duplicate(tmp_path):
    """Scripted workers, no pool: delivery d0 of chunk 0 is claimed and
    stalls; the manager re-queues as d1; a healthy worker reports d1
    (accepted — first to land); the stalled ghost then reports a
    CONFLICTING d0 result, which must be ignored and garbage-collected
    with the job."""
    mq = str(tmp_path)
    backend = QueueBackend(fn_spec=SPEC, num_workers=2, run_id="w",
                           lease_s=0.3, keep_jobs=4, chunk_timeout_s=60,
                           poll_interval_s=0.005, mq_dir=mq)
    g = np.arange(8, dtype=np.float32).reshape(4, 2)     # 2 chunks of 2
    box = {}
    t = threading.Thread(
        target=lambda: box.update(out=backend._host_eval(g)), daemon=True)
    t.start()
    tasks = os.path.join(mq, TASKS_DIR)
    claimed = os.path.join(mq, CLAIMED_DIR)
    d0 = task_name("w", 0, 0, 0, 0)
    c1 = task_name("w", 0, 1, 0, 0)
    d1 = task_name("w", 0, 0, 0, 1)

    def wait_for(path, timeout=10.0):
        deadline = time.monotonic() + timeout
        while not os.path.exists(path):
            assert time.monotonic() < deadline, f"never appeared: {path}"
            time.sleep(0.005)

    wait_for(os.path.join(tasks, d0))
    # scripted worker 1 claims d0, writes its lease once, and stalls
    os.rename(os.path.join(tasks, d0), os.path.join(claimed, d0))
    with open(os.path.join(claimed, d0) + LEASE_SUFFIX, "w") as f:
        f.write("ghost")
    # the manager detects the stale lease and re-queues as delivery d1
    wait_for(os.path.join(tasks, d1))
    # scripted worker 2 claims d1 and reports the CORRECT result
    os.rename(os.path.join(tasks, d1), os.path.join(claimed, d1))
    good = hostsim.sphere(g[:2])
    atomic_savez(mq_result_path(mq, d1), fitness=good,
                  duration=np.float64(0.01))
    os.remove(os.path.join(claimed, d1))
    time.sleep(0.5)          # ample manager sweeps to ACCEPT d1 first
    # the ghost wakes up and reports a conflicting late duplicate for the
    # superseded d0 delivery — at-least-once allows this to happen
    atomic_savez(mq_result_path(mq, d0),
                  fitness=np.full_like(good, 777.0),
                  duration=np.float64(9.9))
    time.sleep(0.1)
    # serve chunk 1 normally so the job can finish
    os.rename(os.path.join(tasks, c1), os.path.join(claimed, c1))
    atomic_savez(mq_result_path(mq, c1), fitness=hostsim.sphere(g[2:]),
                  duration=np.float64(0.01))
    os.remove(os.path.join(claimed, c1))
    t.join(timeout=30)
    assert not t.is_alive()
    # the FIRST result to land (d1) won; the 777 duplicate never leaked
    np.testing.assert_allclose(box["out"][:2], good, rtol=1e-6)
    np.testing.assert_allclose(box["out"], hostsim.sphere(g), rtol=1e-6)
    # ...and the job epilogue swept the duplicate, keeping one winner
    results = sorted(os.path.basename(p) for p in
                     glob.glob(str(tmp_path / RESULTS_DIR / "*")))
    assert not os.path.exists(mq_result_path(mq, d0))
    chunk0 = [r for r in results if "_c0000_" in r]
    assert chunk0 == [os.path.basename(mq_result_path(mq, d1))]
    backend.close()
