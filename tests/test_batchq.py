"""Batch-scheduled dispatch (SLURM-style array jobs): spool protocol,
schedulers, timeout/re-queue, and DispatchBackend conformance."""
import json
import os
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.broker import (Broker, ChunkFailure, DispatchBackend,
                               HostPoolBackend, run_chunks_retry)
from repro.fitness import sphere
from repro.fitness import hostsim
from repro.runtime.batchq import (LocalMockScheduler, SlurmArrayBackend,
                                  SlurmScheduler, _atomic_savez, chunk_path,
                                  fail_path, result_path, run_worker)

SPEC = "repro.fitness.hostsim:sphere"


# ---------------------------------------------------------------------------
# shared DispatchBackend conformance (the paper's pluggable simulation
# container: every decoupled backend must behave identically)
# ---------------------------------------------------------------------------

def _conformance(backend, n=29):
    genomes = jax.random.uniform(jax.random.PRNGKey(0), (n, 5))
    direct = np.asarray(sphere(genomes))
    assert isinstance(backend, DispatchBackend)
    # eager and jitted evaluation match inline fitness
    np.testing.assert_allclose(np.asarray(backend(genomes)), direct,
                               rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(jax.jit(backend.__call__)(genomes)), direct, rtol=1e-6)
    # composes with the broker's padded balanced dispatch under jit
    broker = Broker(cost_fn=lambda g: jnp.sum(jnp.abs(g), -1) + 0.1,
                    num_workers=4, backend=backend)
    fit, stats = jax.jit(broker.evaluate)(genomes)
    np.testing.assert_allclose(np.asarray(fit), direct, rtol=1e-6)
    assert float(stats["balanced"]) == 1.0
    assert int(stats["padded"]) == (-(-n // 4) * 4) - n


class TestConformance:
    def test_slurm_array_backend_mock_thread(self, tmp_path):
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=3,
                               scheduler=LocalMockScheduler(mode="thread"),
                               spool_dir=str(tmp_path), chunk_timeout_s=60,
                               poll_interval_s=0.005) as backend:
            _conformance(backend)
        assert backend.stats["retries"] == 0

    def test_host_pool_backend_same_contract(self):
        with HostPoolBackend(hostsim.sphere, num_workers=3,
                             chunk_timeout_s=60) as backend:
            _conformance(backend)

    def test_attempt_zero_is_one_array_submission(self, tmp_path):
        """All first-attempt chunks go out as ONE scheduler submission
        (one `sbatch --array` round-trip), not one per chunk."""
        sched = LocalMockScheduler(mode="thread")
        calls = []
        orig_submit = sched.submit

        def counting_submit(paths, *, job_dir):
            calls.append(list(paths))
            return orig_submit(paths, job_dir=job_dir)

        sched.submit = counting_submit
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=4,
                               scheduler=sched, spool_dir=str(tmp_path),
                               chunk_timeout_s=60,
                               poll_interval_s=0.005) as backend:
            backend._host_eval(np.ones((16, 3), np.float32))
        assert len(calls) == 1
        assert len(calls[0]) == 4

    def test_pickled_fitness_thread_mode(self, tmp_path):
        # no import spec: the worker unpickles the callable from the spool
        with SlurmArrayBackend(hostsim.rastrigin, num_workers=2,
                               scheduler=LocalMockScheduler(mode="thread"),
                               spool_dir=str(tmp_path), chunk_timeout_s=60,
                               poll_interval_s=0.005) as backend:
            g = jax.random.uniform(jax.random.PRNGKey(1), (11, 4))
            np.testing.assert_allclose(np.asarray(backend(g)),
                                       hostsim.rastrigin(np.asarray(g)),
                                       rtol=1e-5)

    @pytest.mark.slow
    def test_slurm_array_backend_mock_subprocess_e2e(self, tmp_path):
        """End-to-end against real array-task subprocesses (numpy-only
        worker startup; multi-second interpreter spawns -> slow lane)."""
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=2,
                               scheduler=LocalMockScheduler(
                                   mode="subprocess"),
                               spool_dir=str(tmp_path),
                               chunk_timeout_s=300,
                               poll_interval_s=0.05) as backend:
            _conformance(backend, n=17)


# ---------------------------------------------------------------------------
# timeout + re-queue (the acceptance case: a straggler chunk times out and
# the retry succeeds)
# ---------------------------------------------------------------------------

class TestTimeoutRetry:
    def test_straggler_times_out_retry_succeeds(self, tmp_path):
        # attempt 0 of chunk 1 is accepted by the scheduler but never
        # starts (a lost node); the per-chunk timeout fires and the
        # re-queued try1 file runs normally
        sched = LocalMockScheduler(mode="thread",
                                   hang_substrings=("chunk_0001_try0",))
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=2,
                               scheduler=sched, spool_dir=str(tmp_path),
                               chunk_timeout_s=0.5, max_retries=2,
                               poll_interval_s=0.005) as backend:
            g = jax.random.uniform(jax.random.PRNGKey(2), (24, 3))
            out = np.asarray(backend(g))
            np.testing.assert_allclose(out, np.asarray(sphere(g)),
                                       rtol=1e-6)
            # the lost chunk timed out at least once and its re-queue
            # delivered the result (a loaded CI box may time out the
            # healthy chunk too — >= not ==)
            assert backend.stats["timeouts"] >= 1
            assert backend.stats["retries"] >= 1

    def test_pending_queue_time_is_not_straggling(self, tmp_path):
        """A busy partition keeps work items PENDING past the chunk
        timeout; the straggler clock must only start once the item leaves
        the queue (no spurious cancel/re-queue)."""
        import time as _time

        class QueueingScheduler:
            name = "queueing"

            def __init__(self, delay_s):
                self.inner = LocalMockScheduler(mode="thread")
                self.delay_s = delay_s
                self._tasks = {}
                self._n = 0

            def submit(self, paths, *, job_dir):
                handles = []
                for p in paths:
                    h = f"q{self._n}"
                    self._n += 1
                    self._tasks[h] = [p, job_dir,
                                      _time.monotonic() + self.delay_s,
                                      None]
                    handles.append(h)
                return handles

            def poll(self, handle):
                path, job_dir, release, inner_h = self._tasks[handle]
                if inner_h is None:
                    if _time.monotonic() < release:
                        return "pending"
                    (inner_h,) = self.inner.submit([path],
                                                   job_dir=job_dir)
                    self._tasks[handle][3] = inner_h
                    return "running"
                return self.inner.poll(inner_h)

            def cancel(self, handle):
                pass

        # queue delay (0.6s) far exceeds the chunk timeout (0.2s)
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=2,
                               scheduler=QueueingScheduler(0.6),
                               spool_dir=str(tmp_path),
                               chunk_timeout_s=0.2, max_retries=0,
                               poll_interval_s=0.01) as backend:
            g = jax.random.uniform(jax.random.PRNGKey(7), (12, 3))
            out = np.asarray(backend(g))
            np.testing.assert_allclose(out, np.asarray(sphere(g)),
                                       rtol=1e-6)
            assert backend.stats["timeouts"] == 0

    def test_failing_chunk_exhausts_retries(self, tmp_path):
        with SlurmArrayBackend(fn_spec="repro.fitness.hostsim:always_fail",
                               num_workers=2,
                               scheduler=LocalMockScheduler(mode="thread"),
                               spool_dir=str(tmp_path), chunk_timeout_s=30,
                               max_retries=1,
                               poll_interval_s=0.005) as backend:
            with pytest.raises(ChunkFailure, match="simulated simulator"):
                backend._host_eval(np.ones((6, 2), np.float32))
            assert backend.stats["retries"] == 1     # 1 re-queue, then out

    def test_run_chunks_retry_requeues_then_raises(self):
        """The shared driver (used by HostPool + SlurmArray backends)."""
        log = []

        def submit(i, chunk, attempt):
            log.append(("submit", i, attempt))
            return (i, attempt)

        def wait(i, token, timeout_s):
            if token == (1, 0):
                raise TimeoutError("straggler")
            return token

        out = run_chunks_retry(["a", "b"], submit, wait, max_retries=1)
        assert out == [(0, 0), (1, 1)]
        assert ("submit", 1, 1) in log
        with pytest.raises(ChunkFailure):
            run_chunks_retry(["a", "b"], submit,
                             lambda i, t, s: (_ for _ in ()).throw(
                                 RuntimeError("dead")),
                             max_retries=2)


# ---------------------------------------------------------------------------
# worker protocol (spool files)
# ---------------------------------------------------------------------------

def _make_job(tmp_path, fn_spec=SPEC, fn=None):
    job = os.path.join(str(tmp_path), "job_000000")
    os.makedirs(job)
    with open(os.path.join(job, "payload.json"), "w") as f:
        json.dump({"num_objectives": 1, "fn_spec": fn_spec}, f)
    if fn is not None:
        with open(os.path.join(job, "fn.pkl"), "wb") as f:
            pickle.dump(fn, f)
    return job


class TestWorkerProtocol:
    def test_worker_roundtrip(self, tmp_path):
        job = _make_job(tmp_path)
        chunk = chunk_path(job, 0, 0)
        g = np.random.default_rng(0).uniform(-1, 1, (7, 3)).astype(
            np.float32)
        _atomic_savez(chunk, genomes=g)
        assert run_worker(chunk) == 0
        with np.load(result_path(chunk)) as d:
            np.testing.assert_allclose(d["fitness"], hostsim.sphere(g),
                                       rtol=1e-6)
            assert float(d["duration"]) >= 0.0

    def test_worker_failure_writes_marker(self, tmp_path):
        job = _make_job(tmp_path, fn_spec="repro.fitness.hostsim:"
                                          "always_fail")
        chunk = chunk_path(job, 0, 0)
        _atomic_savez(chunk, genomes=np.zeros((3, 2), np.float32))
        assert run_worker(chunk) == 1
        assert not os.path.exists(result_path(chunk))
        with open(fail_path(chunk)) as f:
            assert "simulated simulator crash" in f.read()

    def test_worker_pickled_fallback(self, tmp_path):
        job = _make_job(tmp_path, fn_spec=None, fn=hostsim.griewank)
        chunk = chunk_path(job, 2, 1)
        g = np.random.default_rng(1).uniform(-1, 1, (5, 4)).astype(
            np.float32)
        _atomic_savez(chunk, genomes=g)
        assert run_worker(chunk) == 0
        with np.load(result_path(chunk)) as d:
            np.testing.assert_allclose(d["fitness"], hostsim.griewank(g),
                                       rtol=1e-5)


# ---------------------------------------------------------------------------
# real SLURM scheduler: command construction (no sbatch in CI — shell-outs
# are monkeypatched and inspected)
# ---------------------------------------------------------------------------

class _FakeRun:
    def __init__(self, stdout="", returncode=0):
        self.calls = []
        self.stdout = stdout
        self.returncode = returncode

    def __call__(self, cmd, **kw):
        self.calls.append(list(cmd))

        class R:
            pass

        r = R()
        r.returncode = self.returncode
        r.stdout = self.stdout
        r.stderr = ""
        return r


class TestSlurmScheduler:
    def test_sbatch_array_submission(self, tmp_path, monkeypatch):
        fake = _FakeRun(stdout="4242\n")
        monkeypatch.setattr("repro.runtime.batchq.subprocess.run", fake)
        sched = SlurmScheduler(partition="compute",
                               time_limit="01:00:00")
        chunks = [chunk_path(str(tmp_path), i, 0) for i in range(3)]
        handles = sched.submit(chunks, job_dir=str(tmp_path))
        assert handles == ["4242_0", "4242_1", "4242_2"]
        cmd = fake.calls[0]
        assert cmd[0] == "sbatch"
        assert "--parsable" in cmd and "--array=0-2" in cmd
        script = open(cmd[-1]).read()
        assert "#SBATCH --partition=compute" in script
        assert "#SBATCH --time=01:00:00" in script
        assert "SLURM_ARRAY_TASK_ID" in script
        assert "-m repro.runtime.batchq" in script
        # the manifest maps task ids to spooled chunk paths
        manifest = [l for l in script.splitlines() if "manifest_" in l]
        assert manifest
        mpath = os.path.join(str(tmp_path), "manifest_0000.txt")
        assert open(mpath).read().splitlines() == chunks

    def test_poll_state_mapping(self, monkeypatch):
        sched = SlurmScheduler()
        for stdout, rc, want in (("RUNNING\n", 0, "running"),
                                 ("PENDING\n", 0, "pending"),
                                 ("", 0, "done"),
                                 ("FAILED\n", 0, "failed"),
                                 ("", 1, "unknown")):
            monkeypatch.setattr("repro.runtime.batchq.subprocess.run",
                                _FakeRun(stdout=stdout, returncode=rc))
            assert sched.poll("4242_0") == want

    def test_cancel(self, monkeypatch):
        fake = _FakeRun()
        monkeypatch.setattr("repro.runtime.batchq.subprocess.run", fake)
        SlurmScheduler().cancel("4242_1")
        assert fake.calls == [["scancel", "4242_1"]]
