"""Batch-scheduled dispatch (SLURM arrays + Kubernetes indexed Jobs):
spool protocol, schedulers, timeout/re-queue, cost-sized chunking, spool
GC, and DispatchBackend conformance."""
import glob
import json
import os
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.broker import (Broker, ChunkFailure, DispatchBackend,
                               HostPoolBackend, run_chunks_retry)
from repro.core.hostbridge import cost_sized_chunk_sizes
from repro.fitness import sphere
from repro.fitness import hostsim
from repro.runtime.batchq import (KubernetesScheduler, LocalMockScheduler,
                                  MockKubectl, SlurmArrayBackend,
                                  SlurmScheduler, _compress_index_set,
                                  _parse_index_set, chunk_path, fail_path,
                                  result_path, run_worker)
from repro.runtime.fsatomic import atomic_savez

SPEC = "repro.fitness.hostsim:sphere"

# the shared DispatchBackend contract (eager/jit parity, padded-broker
# compose, pickled fitness, drain-before-close, timeout->retry) lives in
# backend_conformance.py, parametrized over ALL decoupled backends; this
# module reuses its acceptance block for backend-specific variants
from backend_conformance import run_conformance as _conformance  # noqa: E402


class TestConformance:
    def test_slurm_array_backend_mock_thread(self, tmp_path):
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=3,
                               scheduler=LocalMockScheduler(mode="thread"),
                               spool_dir=str(tmp_path), chunk_timeout_s=60,
                               poll_interval_s=0.005) as backend:
            _conformance(backend)
        assert backend.stats["retries"] == 0

    def test_k8s_backend_mock_thread(self, tmp_path):
        """The K8s leg of the portability pair passes the identical
        conformance suite: same backend, same spool, only the scheduler
        (indexed Jobs via a mocked kubectl) differs."""
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=3,
                               scheduler=KubernetesScheduler(
                                   runner=MockKubectl(mode="thread")),
                               spool_dir=str(tmp_path), chunk_timeout_s=60,
                               poll_interval_s=0.005) as backend:
            _conformance(backend)
        assert backend.stats["retries"] == 0

    def test_k8s_equal_chunking_conformance(self, tmp_path):
        # the legacy equal split stays available behind the same backend
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=3,
                               chunk_sizing="equal",
                               scheduler=KubernetesScheduler(
                                   runner=MockKubectl(mode="thread")),
                               spool_dir=str(tmp_path), chunk_timeout_s=60,
                               poll_interval_s=0.005) as backend:
            _conformance(backend)

    def test_host_pool_backend_same_contract(self):
        with HostPoolBackend(hostsim.sphere, num_workers=3,
                             chunk_timeout_s=60) as backend:
            _conformance(backend)

    def test_attempt_zero_is_one_array_submission(self, tmp_path):
        """All first-attempt chunks go out as ONE scheduler submission
        (one `sbatch --array` round-trip), not one per chunk."""
        sched = LocalMockScheduler(mode="thread")
        calls = []
        orig_submit = sched.submit

        def counting_submit(paths, *, job_dir):
            calls.append(list(paths))
            return orig_submit(paths, job_dir=job_dir)

        sched.submit = counting_submit
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=4,
                               scheduler=sched, spool_dir=str(tmp_path),
                               chunk_timeout_s=60,
                               poll_interval_s=0.005) as backend:
            backend._host_eval(np.ones((16, 3), np.float32))
        assert len(calls) == 1
        assert len(calls[0]) == 4

    def test_pickled_fitness_thread_mode(self, tmp_path):
        # no import spec: the worker unpickles the callable from the spool
        with SlurmArrayBackend(hostsim.rastrigin, num_workers=2,
                               scheduler=LocalMockScheduler(mode="thread"),
                               spool_dir=str(tmp_path), chunk_timeout_s=60,
                               poll_interval_s=0.005) as backend:
            g = jax.random.uniform(jax.random.PRNGKey(1), (11, 4))
            np.testing.assert_allclose(np.asarray(backend(g)),
                                       hostsim.rastrigin(np.asarray(g)),
                                       rtol=1e-5)

    @pytest.mark.slow
    def test_slurm_array_backend_mock_subprocess_e2e(self, tmp_path):
        """End-to-end against real array-task subprocesses (numpy-only
        worker startup; multi-second interpreter spawns -> slow lane)."""
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=2,
                               scheduler=LocalMockScheduler(
                                   mode="subprocess"),
                               spool_dir=str(tmp_path),
                               chunk_timeout_s=300,
                               poll_interval_s=0.05) as backend:
            _conformance(backend, n=17)

    @pytest.mark.slow
    def test_k8s_backend_mock_subprocess_e2e(self, tmp_path):
        """K8s-mock end-to-end on real worker subprocesses (the 'pods'),
        slow-marked like the SLURM variant."""
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=2,
                               scheduler=KubernetesScheduler(
                                   runner=MockKubectl(mode="subprocess")),
                               spool_dir=str(tmp_path),
                               chunk_timeout_s=300,
                               poll_interval_s=0.05) as backend:
            _conformance(backend, n=17)


# ---------------------------------------------------------------------------
# timeout + re-queue (the acceptance case: a straggler chunk times out and
# the retry succeeds)
# ---------------------------------------------------------------------------

class TestTimeoutRetry:
    def test_straggler_times_out_retry_succeeds(self, tmp_path):
        # attempt 0 of chunk 1 is accepted by the scheduler but never
        # starts (a lost node); the per-chunk timeout fires and the
        # re-queued try1 file runs normally
        sched = LocalMockScheduler(mode="thread",
                                   hang_substrings=("chunk_0001_try0",))
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=2,
                               scheduler=sched, spool_dir=str(tmp_path),
                               chunk_timeout_s=0.5, max_retries=2,
                               poll_interval_s=0.005) as backend:
            g = jax.random.uniform(jax.random.PRNGKey(2), (24, 3))
            out = np.asarray(backend(g))
            np.testing.assert_allclose(out, np.asarray(sphere(g)),
                                       rtol=1e-6)
            # the lost chunk timed out at least once and its re-queue
            # delivered the result (a loaded CI box may time out the
            # healthy chunk too — >= not ==)
            assert backend.stats["timeouts"] >= 1
            assert backend.stats["retries"] >= 1

    def test_k8s_lost_pod_times_out_retry_succeeds(self, tmp_path):
        """Same acceptance case on the K8s path: a lost pod (accepted by
        the control plane, never started) times out; K8s can't cancel a
        single index so the re-queued single-completion Job races it and
        delivers."""
        kubectl = MockKubectl(mode="thread",
                              hang_substrings=("chunk_0001_try0",))
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=2,
                               scheduler=KubernetesScheduler(
                                   runner=kubectl),
                               spool_dir=str(tmp_path),
                               chunk_timeout_s=0.5, max_retries=2,
                               poll_interval_s=0.005) as backend:
            g = jax.random.uniform(jax.random.PRNGKey(3), (20, 3))
            out = np.asarray(backend(g))
            np.testing.assert_allclose(out, np.asarray(sphere(g)),
                                       rtol=1e-6)
            assert backend.stats["timeouts"] >= 1
            assert backend.stats["retries"] >= 1

    def test_pending_queue_time_is_not_straggling(self, tmp_path):
        """A busy partition keeps work items PENDING past the chunk
        timeout; the straggler clock must only start once the item leaves
        the queue (no spurious cancel/re-queue)."""
        import time as _time

        class QueueingScheduler:
            name = "queueing"

            def __init__(self, delay_s):
                self.inner = LocalMockScheduler(mode="thread")
                self.delay_s = delay_s
                self._tasks = {}
                self._n = 0

            def submit(self, paths, *, job_dir):
                handles = []
                for p in paths:
                    h = f"q{self._n}"
                    self._n += 1
                    self._tasks[h] = [p, job_dir,
                                      _time.monotonic() + self.delay_s,
                                      None]
                    handles.append(h)
                return handles

            def poll(self, handle):
                path, job_dir, release, inner_h = self._tasks[handle]
                if inner_h is None:
                    if _time.monotonic() < release:
                        return "pending"
                    (inner_h,) = self.inner.submit([path],
                                                   job_dir=job_dir)
                    self._tasks[handle][3] = inner_h
                    return "running"
                return self.inner.poll(inner_h)

            def cancel(self, handle):
                pass

        # queue delay (1.0s) far exceeds the chunk timeout (0.4s); the
        # timeout is generous vs the instant eval so a loaded CI box
        # doesn't time out the healthy chunk (0.2s proved too tight)
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=2,
                               scheduler=QueueingScheduler(1.0),
                               spool_dir=str(tmp_path),
                               chunk_timeout_s=0.4, max_retries=0,
                               poll_interval_s=0.01) as backend:
            g = jax.random.uniform(jax.random.PRNGKey(7), (12, 3))
            out = np.asarray(backend(g))
            np.testing.assert_allclose(out, np.asarray(sphere(g)),
                                       rtol=1e-6)
            assert backend.stats["timeouts"] == 0

    def test_failing_chunk_exhausts_retries(self, tmp_path):
        with SlurmArrayBackend(fn_spec="repro.fitness.hostsim:always_fail",
                               num_workers=2,
                               scheduler=LocalMockScheduler(mode="thread"),
                               spool_dir=str(tmp_path), chunk_timeout_s=30,
                               max_retries=1,
                               poll_interval_s=0.005) as backend:
            with pytest.raises(ChunkFailure, match="simulated simulator"):
                backend._host_eval(np.ones((6, 2), np.float32))
            assert backend.stats["retries"] == 1     # 1 re-queue, then out

    def test_run_chunks_retry_requeues_then_raises(self):
        """The shared driver (used by HostPool + SlurmArray backends)."""
        log = []

        def submit(i, chunk, attempt):
            log.append(("submit", i, attempt))
            return (i, attempt)

        def wait(i, token, timeout_s):
            if token == (1, 0):
                raise TimeoutError("straggler")
            return token

        out = run_chunks_retry(["a", "b"], submit, wait, max_retries=1)
        assert out == [(0, 0), (1, 1)]
        assert ("submit", 1, 1) in log
        with pytest.raises(ChunkFailure):
            run_chunks_retry(["a", "b"], submit,
                             lambda i, t, s: (_ for _ in ()).throw(
                                 RuntimeError("dead")),
                             max_retries=2)


# ---------------------------------------------------------------------------
# worker protocol (spool files)
# ---------------------------------------------------------------------------

def _make_job(tmp_path, fn_spec=SPEC, fn=None):
    job = os.path.join(str(tmp_path), "job_000000")
    os.makedirs(job)
    with open(os.path.join(job, "payload.json"), "w") as f:
        json.dump({"num_objectives": 1, "fn_spec": fn_spec}, f)
    if fn is not None:
        with open(os.path.join(job, "fn.pkl"), "wb") as f:
            pickle.dump(fn, f)
    return job


class TestWorkerProtocol:
    def test_worker_roundtrip(self, tmp_path):
        job = _make_job(tmp_path)
        chunk = chunk_path(job, 0, 0)
        g = np.random.default_rng(0).uniform(-1, 1, (7, 3)).astype(
            np.float32)
        atomic_savez(chunk, genomes=g)
        assert run_worker(chunk) == 0
        with np.load(result_path(chunk)) as d:
            np.testing.assert_allclose(d["fitness"], hostsim.sphere(g),
                                       rtol=1e-6)
            assert float(d["duration"]) >= 0.0

    def test_worker_failure_writes_marker(self, tmp_path):
        job = _make_job(tmp_path, fn_spec="repro.fitness.hostsim:"
                                          "always_fail")
        chunk = chunk_path(job, 0, 0)
        atomic_savez(chunk, genomes=np.zeros((3, 2), np.float32))
        assert run_worker(chunk) == 1
        assert not os.path.exists(result_path(chunk))
        with open(fail_path(chunk)) as f:
            assert "simulated simulator crash" in f.read()

    def test_worker_pickled_fallback(self, tmp_path):
        job = _make_job(tmp_path, fn_spec=None, fn=hostsim.griewank)
        chunk = chunk_path(job, 2, 1)
        g = np.random.default_rng(1).uniform(-1, 1, (5, 4)).astype(
            np.float32)
        atomic_savez(chunk, genomes=g)
        assert run_worker(chunk) == 0
        with np.load(result_path(chunk)) as d:
            np.testing.assert_allclose(d["fitness"], hostsim.griewank(g),
                                       rtol=1e-5)


# ---------------------------------------------------------------------------
# real SLURM scheduler: command construction (no sbatch in CI — shell-outs
# are monkeypatched and inspected)
# ---------------------------------------------------------------------------

class _FakeRun:
    def __init__(self, stdout="", returncode=0):
        self.calls = []
        self.stdout = stdout
        self.returncode = returncode

    def __call__(self, cmd, **kw):
        self.calls.append(list(cmd))

        class R:
            pass

        r = R()
        r.returncode = self.returncode
        r.stdout = self.stdout
        r.stderr = ""
        return r


class TestSlurmScheduler:
    def test_sbatch_array_submission(self, tmp_path, monkeypatch):
        fake = _FakeRun(stdout="4242\n")
        monkeypatch.setattr("repro.runtime.batchq.subprocess.run", fake)
        sched = SlurmScheduler(partition="compute",
                               time_limit="01:00:00")
        chunks = [chunk_path(str(tmp_path), i, 0) for i in range(3)]
        handles = sched.submit(chunks, job_dir=str(tmp_path))
        assert handles == ["4242_0", "4242_1", "4242_2"]
        cmd = fake.calls[0]
        assert cmd[0] == "sbatch"
        assert "--parsable" in cmd and "--array=0-2" in cmd
        script = open(cmd[-1]).read()
        assert "#SBATCH --partition=compute" in script
        assert "#SBATCH --time=01:00:00" in script
        assert "SLURM_ARRAY_TASK_ID" in script
        assert "-m repro.runtime.batchq" in script
        # the manifest maps task ids to spooled chunk paths
        manifest = [l for l in script.splitlines() if "manifest_" in l]
        assert manifest
        mpath = os.path.join(str(tmp_path), "manifest_0000.txt")
        assert open(mpath).read().splitlines() == chunks

    def test_poll_state_mapping(self, monkeypatch):
        sched = SlurmScheduler()
        for stdout, rc, want in (("RUNNING\n", 0, "running"),
                                 ("PENDING\n", 0, "pending"),
                                 ("", 0, "done"),
                                 ("FAILED\n", 0, "failed"),
                                 ("", 1, "unknown")):
            monkeypatch.setattr("repro.runtime.batchq.subprocess.run",
                                _FakeRun(stdout=stdout, returncode=rc))
            assert sched.poll("4242_0") == want

    def test_cancel(self, monkeypatch):
        fake = _FakeRun()
        monkeypatch.setattr("repro.runtime.batchq.subprocess.run", fake)
        SlurmScheduler().cancel("4242_1")
        assert fake.calls == [["scancel", "4242_1"]]


# ---------------------------------------------------------------------------
# Kubernetes scheduler: command construction + state mapping (no cluster —
# kubectl invocations are captured by a recording runner)
# ---------------------------------------------------------------------------

class _RecordingKubectl:
    """Runner that records commands and replays canned responses."""

    def __init__(self, responses=()):
        self.calls = []
        self.responses = list(responses)

    def __call__(self, cmd):
        self.calls.append(list(cmd))

        class R:
            returncode = 0
            stdout = ""
            stderr = ""

        r = R()
        if self.responses:
            rc, stdout = self.responses.pop(0)
            r.returncode, r.stdout = rc, stdout
        return r


class TestKubernetesScheduler:
    def test_index_set_roundtrip(self):
        assert _parse_index_set("1,3-5,7") == {1, 3, 4, 5, 7}
        assert _parse_index_set("") == set()
        assert _parse_index_set(None) == set()
        assert _compress_index_set([7, 3, 4, 5, 1]) == "1,3-5,7"
        assert _compress_index_set([]) == ""
        for idxs in ([0], [0, 1, 2], [2, 5], [0, 2, 3, 9]):
            assert _parse_index_set(_compress_index_set(idxs)) == set(idxs)

    def test_apply_indexed_job_submission(self, tmp_path):
        runner = _RecordingKubectl()
        sched = KubernetesScheduler(namespace="ga", image="repo/worker:9",
                                    python="python3", runner=runner,
                                    env={"OMP_NUM_THREADS": 1})
        job_dir = str(tmp_path / "job_000000")
        os.makedirs(job_dir)
        chunks = [chunk_path(job_dir, i, 0) for i in range(3)]
        handles = sched.submit(chunks, job_dir=job_dir)
        # one kubectl round-trip for the whole batch; per-index handles
        assert len(runner.calls) == 1
        cmd = runner.calls[0]
        assert cmd[0] == "kubectl" and cmd[1] == "apply"
        assert cmd[cmd.index("-n") + 1] == "ga"
        assert [h.rpartition("/")[2] for h in handles] == ["0", "1", "2"]
        assert len({h.rpartition("/")[0] for h in handles}) == 1
        with open(cmd[cmd.index("-f") + 1]) as f:
            spec = json.load(f)
        assert spec["kind"] == "Job"
        assert spec["metadata"]["namespace"] == "ga"
        jspec = spec["spec"]
        assert jspec["completionMode"] == "Indexed"
        assert jspec["completions"] == 3 and jspec["parallelism"] == 3
        container = jspec["template"]["spec"]["containers"][0]
        assert container["image"] == "repo/worker:9"
        shell = container["command"][-1]
        # pod i resolves its chunk by completion index and runs the exact
        # SLURM worker entrypoint
        assert "JOB_COMPLETION_INDEX" in shell
        assert "python3 -m repro.runtime.batchq" in shell
        assert {"name": "OMP_NUM_THREADS", "value": "1"} in container["env"]
        # shared-spool contract: the spool root is mounted at its own path
        spool_root = os.path.dirname(os.path.abspath(job_dir))
        assert container["volumeMounts"][0]["mountPath"] == spool_root
        volume = jspec["template"]["spec"]["volumes"][0]
        assert volume["hostPath"]["path"] == spool_root
        # the chunk manifest maps index i -> chunk path
        manifest = spec["metadata"]["annotations"][
            KubernetesScheduler.MANIFEST_ANNOTATION]
        assert open(manifest).read().splitlines() == chunks

    def test_poll_state_mapping(self):
        status_done = json.dumps(
            {"status": {"active": 1, "completedIndexes": "0,2"}})
        status_failed = json.dumps(
            {"status": {"active": 1, "failedIndexes": "1"}})
        status_running = json.dumps({"status": {"active": 2}})
        status_pending = json.dumps({"status": {}})
        status_job_failed = json.dumps(
            {"status": {"conditions": [
                {"type": "Failed", "status": "True"}]}})
        for stdout, rc, idx, want in (
                (status_done, 0, 0, "done"),
                (status_done, 0, 1, "running"),
                (status_failed, 0, 1, "failed"),
                (status_running, 0, 0, "running"),
                (status_pending, 0, 0, "pending"),
                (status_job_failed, 0, 0, "failed"),
                ("", 1, 0, "unknown")):
            sched = KubernetesScheduler(
                runner=_RecordingKubectl([(rc, stdout)]))
            assert sched.poll(f"chambga-eval-1-0000/{idx}") == want

    def test_cancel_deletes_only_single_completion_jobs(self, tmp_path):
        runner = _RecordingKubectl()
        sched = KubernetesScheduler(runner=runner)
        job_dir = str(tmp_path)
        multi = sched.submit([chunk_path(job_dir, i, 0) for i in range(2)],
                             job_dir=job_dir)
        single = sched.submit([chunk_path(job_dir, 1, 1)], job_dir=job_dir)
        n_before = len(runner.calls)
        sched.cancel(multi[0])      # K8s can't cancel one index: no-op
        assert len(runner.calls) == n_before
        sched.cancel(single[0])     # re-queue jobs are deleted outright
        cmd = runner.calls[-1]
        assert cmd[:3] == ["kubectl", "delete", "job"]
        assert cmd[3] == single[0].rpartition("/")[0]

    def test_reap_deletes_all_batch_jobs(self, tmp_path):
        runner = _RecordingKubectl()
        sched = KubernetesScheduler(runner=runner)
        handles = sched.submit(
            [chunk_path(str(tmp_path), i, 0) for i in range(2)],
            job_dir=str(tmp_path))
        handles += sched.submit([chunk_path(str(tmp_path), 0, 1)],
                                job_dir=str(tmp_path))
        sched.reap(handles)
        deleted = {c[3] for c in runner.calls if c[1] == "delete"}
        assert deleted == {h.rpartition("/")[0] for h in handles}
        # reap is idempotent: forgotten jobs are not re-deleted
        n = len(runner.calls)
        sched.reap(handles)
        assert len(runner.calls) == n


# ---------------------------------------------------------------------------
# spool garbage collection (keep_jobs pruning + superseded attempts)
# ---------------------------------------------------------------------------

class TestSpoolGC:
    def test_long_run_keeps_at_most_keep_jobs_dirs(self, tmp_path):
        """The acceptance case: job_* dirs must not accumulate unbounded
        over a long run (one per epoch per evaluate)."""
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=2, keep_jobs=3,
                               scheduler=LocalMockScheduler(mode="thread"),
                               spool_dir=str(tmp_path), chunk_timeout_s=60,
                               poll_interval_s=0.005) as backend:
            g = np.ones((8, 3), np.float32)
            for _ in range(10):
                backend._host_eval(g)
            assert backend.stats["jobs"] == 10
            assert backend.stats["jobs_pruned"] == 7
            remaining = sorted(os.path.basename(d) for d in
                               glob.glob(str(tmp_path / "job_*")))
            # the newest keep_jobs survive, oldest are pruned
            assert remaining == ["job_000007", "job_000008", "job_000009"]

    def test_keep_jobs_none_disables_pruning(self, tmp_path):
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=2, keep_jobs=None,
                               scheduler=LocalMockScheduler(mode="thread"),
                               spool_dir=str(tmp_path), chunk_timeout_s=60,
                               poll_interval_s=0.005) as backend:
            for _ in range(4):
                backend._host_eval(np.ones((6, 2), np.float32))
            assert len(glob.glob(str(tmp_path / "job_*"))) == 4

    def test_superseded_attempt_files_pruned(self, tmp_path):
        """Once a later attempt succeeds, the straggler's try0 files are
        dead weight on the shared filesystem and must be deleted; the
        winning attempt's files survive until the job dir itself is
        pruned."""
        sched = LocalMockScheduler(mode="thread",
                                   hang_substrings=("chunk_0001_try0",))
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=2, keep_jobs=4,
                               scheduler=sched, spool_dir=str(tmp_path),
                               chunk_timeout_s=0.5, max_retries=2,
                               poll_interval_s=0.005) as backend:
            backend._host_eval(np.ones((8, 3), np.float32))
        (job_dir,) = glob.glob(str(tmp_path / "job_*"))
        names = set(os.listdir(job_dir))
        assert "chunk_0001_try0.npz" not in names          # superseded
        assert "chunk_0001_try1.npz" in names              # the winner
        assert "chunk_0001_try1.result.npz" in names
        # exactly one attempt per chunk survives, and it carries a result
        # (a loaded CI box may have retried the healthy chunk too — the
        # invariant is one winner per index, not which attempt won)
        for idx in (0, 1):
            kept = [n for n in names
                    if n.startswith(f"chunk_{idx:04d}_try")
                    and n.endswith(".npz") and ".result" not in n]
            assert len(kept) == 1
            assert kept[0][:-len(".npz")] + ".result.npz" in names


# ---------------------------------------------------------------------------
# cost-sized chunking (adaptive chunk sizing: array tasks finish together)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(n=st.integers(1, 96), w=st.integers(1, 14),
       seed=st.integers(0, 2**30), skew=st.floats(0.5, 3.0))
def test_cost_sized_chunk_size_invariants(n, w, seed, skew):
    """Sizes always sum to N, are >= 1, one per (capped) worker, and are
    monotone in predicted cost: for distinct costs sorted descending, the
    priciest chunk never holds more items than the cheapest. Per-chunk
    predicted cost is within one item of the ideal equal share, and
    scaling costs by a power of two (exact in fp) leaves the split
    unchanged."""
    rng = np.random.default_rng(seed)
    cost = np.sort(rng.uniform(0.01, 1.0, n) ** skew)[::-1].copy()
    cost += np.linspace(1e-6 * n, 0.0, n)        # break ties: distinct
    sizes = cost_sized_chunk_sizes(cost, w)
    weff = min(w, n)
    assert len(sizes) == weff
    assert sum(sizes) == n
    assert min(sizes) >= 1
    assert sizes[0] <= sizes[-1]                 # monotone in cost
    bounds = np.cumsum(sizes)
    chunk_costs = np.diff(np.concatenate(
        [[0.0], np.cumsum(cost)[bounds - 1]]))
    total = float(cost.sum())
    assert chunk_costs.max() <= total / weff + cost.max() + 1e-9
    assert cost_sized_chunk_sizes(cost * 32.0, w) == sizes


@settings(max_examples=60, deadline=None)
@given(n=st.integers(1, 96), w=st.integers(1, 12),
       seed=st.integers(0, 2**30), frac=st.floats(0.0, 2.0))
def test_folded_chunk_sizes_invariants(n, w, seed, frac):
    """min_chunk_cost folding (worker-side batching of tiny chunks,
    shared by batchq and mq) preserves the core laws: folded sizes still
    sum to N with every size >= 1, the chunk count never grows, and what
    remains is either a single chunk or chunks that all clear the
    floor."""
    rng = np.random.default_rng(seed)
    cost = np.sort(rng.uniform(0.01, 1.0, n))[::-1].copy()
    floor = frac * float(cost.sum()) / max(w, 1)
    sizes = cost_sized_chunk_sizes(cost, w, min_chunk_cost=floor)
    assert sum(sizes) == n
    assert min(sizes) >= 1
    assert len(sizes) <= len(cost_sized_chunk_sizes(cost, w))
    if len(sizes) > 1:
        bounds = np.concatenate([[0], np.cumsum(sizes)]).astype(int)
        chunk_costs = np.add.reduceat(cost, bounds[:-1])
        assert float(chunk_costs.min()) >= floor - 1e-9


class TestCostSizedChunks:
    def test_fold_merges_into_cheaper_neighbor(self):
        # [10, 10, 10, .1, .1] over 5 chunks, floor 1.0: the trailing
        # cheap chunks merge together first (cheaper neighbor), then the
        # still-sub-floor pair folds into the adjacent pricey chunk
        sizes = cost_sized_chunk_sizes(
            np.array([10.0, 10.0, 10.0, 0.1, 0.1]), 5, min_chunk_cost=1.0)
        assert sizes == [1, 1, 3]

    def test_fold_disabled_by_default(self):
        cost = np.linspace(5.0, 0.01, 17)
        assert (cost_sized_chunk_sizes(cost, 4)
                == cost_sized_chunk_sizes(cost, 4, min_chunk_cost=0.0))

    def test_uniform_cost_matches_equal_split(self):
        for n, w in ((12, 4), (7, 3), (64, 8), (5, 5)):
            sizes = cost_sized_chunk_sizes(np.full(n, 2.5), w)
            equal = [a.size for a in np.array_split(np.arange(n), w)]
            assert sorted(sizes) == sorted(equal)

    def test_degenerate_inputs(self):
        assert cost_sized_chunk_sizes(np.ones(5), 1) == [5]
        assert cost_sized_chunk_sizes(np.ones(0), 4) == []
        assert cost_sized_chunk_sizes(np.ones(2), 7) == [1, 1]
        # zero / non-finite costs degrade to the equal split
        assert sum(cost_sized_chunk_sizes(np.zeros(9), 3)) == 9
        assert sum(cost_sized_chunk_sizes(
            np.asarray([np.inf, np.nan, 1.0, -2.0, 1.0]), 2)) == 5

    def test_padded_dispatch_never_spools_sentinel_rows(self, tmp_path):
        """N % W != 0: the broker pads with duplicates of genome 0 whose
        results are discarded — the cost-sizing backend must skip them
        (marked -inf), not pile the 'free' pads into one chunk that
        silently re-evaluates genome 0 up to W-1 times at its true cost."""
        n, w = 13, 4                             # pads 13 -> 16
        g = jax.random.uniform(jax.random.PRNGKey(11), (n, 3))
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=w, keep_jobs=4,
                               scheduler=LocalMockScheduler(mode="thread"),
                               spool_dir=str(tmp_path), chunk_timeout_s=60,
                               poll_interval_s=0.005) as backend:
            broker = Broker(cost_fn=lambda x: jnp.sum(jnp.abs(x), -1) + 0.1,
                            num_workers=w, backend=backend)
            fit, stats = jax.jit(broker.evaluate)(g)
            np.testing.assert_allclose(np.asarray(fit),
                                       np.asarray(sphere(g)), rtol=1e-6)
            assert int(stats["padded"]) == 3
            (job_dir,) = glob.glob(str(tmp_path / "job_*"))
            spooled = sum(
                np.load(p)["genomes"].shape[0] for p in
                glob.glob(os.path.join(job_dir, "chunk_*_try0.npz")))
            assert spooled == n                  # real rows only, no pads

    def test_hot_genome_isolated_in_small_chunk(self, tmp_path):
        """Integration: a heavily skewed cost model makes the backend
        spool variable-size chunks — the hot genome rides alone while the
        cheap ones spread over the remaining tasks — and fitness still
        lands in the right rows after the host-side re-sort."""
        n, w = 24, 4
        g = jax.random.uniform(jax.random.PRNGKey(9), (n, 5))
        cost_fn = lambda x: jnp.where(jnp.arange(n) == 5, 50.0, 1.0)
        with SlurmArrayBackend(fn_spec=SPEC, num_workers=w, keep_jobs=4,
                               scheduler=LocalMockScheduler(mode="thread"),
                               spool_dir=str(tmp_path), chunk_timeout_s=60,
                               poll_interval_s=0.005) as backend:
            broker = Broker(cost_fn=cost_fn, num_workers=w,
                            backend=backend)
            fit, _ = jax.jit(broker.evaluate)(g)
            np.testing.assert_allclose(np.asarray(fit),
                                       np.asarray(sphere(g)), rtol=1e-6)
            (job_dir,) = glob.glob(str(tmp_path / "job_*"))
            chunk_rows = sorted(
                np.load(p)["genomes"].shape[0] for p in
                glob.glob(os.path.join(job_dir, "chunk_*_try0.npz")))
            assert sum(chunk_rows) == n
            assert chunk_rows[0] == 1            # the hot genome, alone
            assert len(chunk_rows) == w


# ---------------------------------------------------------------------------
# ga_run end-to-end on the k8s-mock dispatch backend (the acceptance run:
# full engine loop -> broker -> spool -> mocked indexed Jobs -> results)
# ---------------------------------------------------------------------------

def test_ga_run_k8s_mock_e2e(tmp_path):
    from repro.launch.ga_run import main
    pop, hist = main(["--fitness", "sphere", "--dispatch-backend",
                      "k8s-mock", "--genes", "4", "--islands", "2",
                      "--pop", "8", "--epochs", "2", "--gens-per-epoch",
                      "2", "--chunk-timeout-s", "60", "--keep-jobs", "2",
                      "--spool-dir", str(tmp_path / "spool")])
    assert len(hist) == 2
    # spool GC held: at most --keep-jobs job dirs left behind
    assert len(glob.glob(str(tmp_path / "spool" / "job_*"))) <= 2
