"""NSGA-II invariants: non-dominated sorting + crowding (with hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import nsga2


def brute_force_ranks(f):
    """Reference front peeling in numpy."""
    n = len(f)
    dominated_by = [
        {i for i in range(n)
         if np.all(f[i] <= f[j]) and np.any(f[i] < f[j])}
        for j in range(n)]
    ranks = np.full(n, -1)
    level = 0
    remaining = set(range(n))
    while remaining:
        front = {j for j in remaining
                 if not (dominated_by[j] & remaining)}
        for j in front:
            ranks[j] = level
        remaining -= front
        level += 1
    return ranks


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(2, 24),
    o=st.integers(1, 3),
    seed=st.integers(0, 2**30),
)
def test_ranks_match_bruteforce(n, o, seed):
    rng = np.random.default_rng(seed)
    f = rng.standard_normal((n, o)).astype(np.float32)
    got = np.asarray(nsga2.nondominated_ranks(jnp.asarray(f)))
    want = brute_force_ranks(f)
    np.testing.assert_array_equal(got, want)


def test_front_zero_nondominated():
    f = jnp.asarray(np.random.default_rng(0).standard_normal((40, 2)),
                    jnp.float32)
    ranks = nsga2.nondominated_ranks(f)
    dom = nsga2.domination_matrix(f)
    front0 = np.where(np.asarray(ranks) == 0)[0]
    assert len(front0) > 0
    # nothing dominates a front-0 member
    assert not np.any(np.asarray(dom)[:, front0])


def test_crowding_boundaries_infinite():
    # 1 objective, distinct values: min and max get BIG distance
    f = jnp.asarray([[1.0], [5.0], [2.0], [9.0]])
    ranks = jnp.zeros(4, jnp.int32)
    d = np.asarray(nsga2.crowding_distance(f, ranks))
    assert d[0] >= nsga2.BIG / 10      # min boundary
    assert d[3] >= nsga2.BIG / 10      # max boundary
    assert d[1] < nsga2.BIG / 10 and d[2] < nsga2.BIG / 10


def test_survivor_select_keeps_elites():
    rng = np.random.default_rng(1)
    f = rng.standard_normal((30, 1)).astype(np.float32)
    g = rng.standard_normal((30, 4)).astype(np.float32)
    sg, sf = nsga2.survivor_select(jnp.asarray(g), jnp.asarray(f), 10)
    # the best individual survives
    best = np.min(f)
    assert np.min(np.asarray(sf)) == best
    # survivors are the 10 best for single objective
    np.testing.assert_allclose(np.sort(np.asarray(sf)[:, 0]),
                               np.sort(f[:, 0])[:10])


def test_single_objective_rank_is_dense_order():
    f = jnp.asarray([[3.0], [1.0], [2.0], [1.0]])
    ranks = np.asarray(nsga2.nondominated_ranks(f))
    assert list(ranks) == [2, 0, 1, 0]
