"""Persistent-worker message queue (repro.runtime.mq): queue protocol,
lease/heartbeat liveness, streaming CostEMA, broker-directory GC,
Scheduler-launched fleets, and DispatchBackend conformance."""
import glob
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.broker import Broker, ChunkFailure, CostEMA
from repro.core.hostbridge import cost_sized_chunk_sizes
from repro.fitness import sphere
from repro.fitness import hostsim
from repro.runtime.batchq import LocalMockScheduler
from repro.runtime.mq import (CLAIMED_DIR, LEASE_SUFFIX, POISON_SUFFIX,
                              RESULTS_DIR, STOP_NAME, TASKS_DIR,
                              FleetAutoscaler, LocalWorkerPool,
                              MQWorkerFleet, QueueBackend, claim_next,
                              make_broker_dirs, parse_task_name,
                              task_name, worker_loop)

from backend_conformance import run_conformance as _conformance

SPEC = "repro.fitness.hostsim:sphere"

FAST = dict(poll_interval_s=0.005, chunk_timeout_s=60)


def _thread_pool(n=3, **kw):
    kw.setdefault("lease_s", 5.0)
    kw.setdefault("poll_s", 0.005)
    return LocalWorkerPool(num_workers=n, mode="thread", **kw)


# ---------------------------------------------------------------------------
# shared DispatchBackend conformance (satellite: the same suite every
# decoupled backend passes, now parametrized over QueueBackend)
# ---------------------------------------------------------------------------

class TestConformance:
    def test_queue_backend_thread_pool(self, tmp_path):
        with QueueBackend(fn_spec=SPEC, num_workers=3,
                          worker_pool=_thread_pool(3),
                          mq_dir=str(tmp_path), **FAST) as backend:
            _conformance(backend)
        assert backend.stats["retries"] == 0
        assert backend.stats["lease_requeues"] == 0

    def test_queue_backend_equal_chunking(self, tmp_path):
        with QueueBackend(fn_spec=SPEC, num_workers=3,
                          chunk_sizing="equal",
                          worker_pool=_thread_pool(3),
                          mq_dir=str(tmp_path), **FAST) as backend:
            _conformance(backend)

    def test_fleet_via_scheduler_protocol(self, tmp_path):
        """The persistent fleet is launched as ONE submission through the
        unchanged batchq Scheduler protocol: each work item receives a
        *.worker.json ticket and becomes a long-lived queue worker."""
        sched = LocalMockScheduler(mode="thread")
        submits = []
        orig_submit = sched.submit

        def counting_submit(paths, *, job_dir):
            submits.append(list(paths))
            return orig_submit(paths, job_dir=job_dir)

        sched.submit = counting_submit
        fleet = MQWorkerFleet(sched, 3, lease_s=5.0, poll_s=0.005)
        with QueueBackend(fn_spec=SPEC, num_workers=3, worker_pool=fleet,
                          mq_dir=str(tmp_path), **FAST) as backend:
            _conformance(backend)
            # one scheduler round-trip launched the whole fleet, and the
            # tickets — not chunks — were what it submitted
            assert len(submits) == 1
            assert all(p.endswith(".worker.json") for p in submits[0])
        # STOP drained the fleet: every scheduler work item has exited
        assert all(sched.poll(h) == "done" for h in fleet.handles)

    def test_pickled_fitness_thread_pool(self, tmp_path):
        # no import spec: workers unpickle the callable from the broker
        with QueueBackend(hostsim.rastrigin, num_workers=2,
                          worker_pool=_thread_pool(2),
                          mq_dir=str(tmp_path), **FAST) as backend:
            g = jax.random.uniform(jax.random.PRNGKey(1), (11, 4))
            np.testing.assert_allclose(np.asarray(backend(g)),
                                       hostsim.rastrigin(np.asarray(g)),
                                       rtol=1e-5)

    @pytest.mark.slow
    def test_subprocess_pool_amortizes_startup(self, tmp_path):
        """Persistent numpy-only worker subprocesses: the SAME
        interpreters serve every generation (fitness = worker PID), where
        a batch backend would spawn fresh array tasks per chunk."""
        with QueueBackend(fn_spec="repro.fitness.hostsim:worker_pid",
                          num_workers=2,
                          worker_pool=LocalWorkerPool(
                              num_workers=2, mode="subprocess",
                              lease_s=10.0),
                          mq_dir=str(tmp_path), poll_interval_s=0.01,
                          chunk_timeout_s=300) as backend:
            g = np.ones((8, 3), np.float32)
            pids1 = set(backend._host_eval(g).ravel().tolist())
            pids2 = set(backend._host_eval(g).ravel().tolist())
            # the fleet's two interpreters serve every chunk of every
            # generation, and at least one is reused across generations
            # (a loaded box may bring worker 2 up only after eval 1 — the
            # invariant is NO fresh interpreter per chunk, not that both
            # evals saw the identical worker subset)
            all_pids = pids1 | pids2
            assert 1 <= len(all_pids) <= 2
            assert pids1 & pids2             # startup amortized: reused
            assert os.getpid() not in all_pids   # and not our interpreter

    @pytest.mark.slow
    def test_fleet_subprocess_e2e(self, tmp_path):
        """Cluster-shaped end-to-end: mock scheduler launches persistent
        worker subprocesses from tickets via the standard batchq
        entrypoint; two evaluates reuse them."""
        fleet = MQWorkerFleet(LocalMockScheduler(mode="subprocess"), 2,
                              lease_s=10.0, poll_s=0.02)
        with QueueBackend(fn_spec=SPEC, num_workers=2, worker_pool=fleet,
                          mq_dir=str(tmp_path), poll_interval_s=0.02,
                          chunk_timeout_s=300) as backend:
            for seed in (2, 3):
                g = jax.random.uniform(jax.random.PRNGKey(seed), (9, 4))
                np.testing.assert_allclose(np.asarray(backend(g)),
                                           np.asarray(sphere(g)),
                                           rtol=1e-6)


# ---------------------------------------------------------------------------
# lease / heartbeat liveness (the queue's replacement for timeout-only
# straggler detection)
# ---------------------------------------------------------------------------

class TestLeases:
    def test_expired_lease_requeued_run_completes(self, tmp_path):
        """Acceptance: a worker claims a task and dies (lease never
        renewed); the manager re-queues it under a bumped delivery and a
        surviving worker completes it — WITHOUT consuming the retry
        budget (liveness, not timeout)."""
        pool = _thread_pool(2, lease_s=0.4,
                            hang_substrings=("c0001_t0_d0",))
        with QueueBackend(fn_spec=SPEC, num_workers=2, worker_pool=pool,
                          lease_s=0.4, chunk_timeout_s=30,
                          poll_interval_s=0.005,
                          mq_dir=str(tmp_path)) as backend:
            broker = Broker(cost_fn=lambda g: jnp.sum(jnp.abs(g), -1) + 0.1,
                            num_workers=2, backend=backend)
            g = jax.random.uniform(jax.random.PRNGKey(2), (14, 3))
            fit, _ = jax.jit(broker.evaluate)(g)
            np.testing.assert_allclose(np.asarray(fit),
                                       np.asarray(sphere(g)), rtol=1e-6)
            assert backend.stats["lease_requeues"] >= 1
            assert backend.stats["retries"] == 0
            assert backend.stats["timeouts"] == 0

    def test_slow_heartbeating_worker_is_not_requeued(self, tmp_path):
        """A worker that is slow but ALIVE keeps its lease fresh via
        heartbeats — the manager must not re-queue it (the heartbeat
        interval is lease/4, so an evaluation several leases long still
        renews in time)."""
        def slow_sphere(genomes):
            time.sleep(0.9)                      # ~3x the lease
            return hostsim.sphere(genomes)

        pool = _thread_pool(2, fn=slow_sphere, lease_s=0.3)
        with QueueBackend(slow_sphere, num_workers=2, worker_pool=pool,
                          lease_s=0.3, chunk_timeout_s=30,
                          poll_interval_s=0.005,
                          mq_dir=str(tmp_path)) as backend:
            g = np.random.default_rng(3).uniform(-1, 1, (6, 3)).astype(
                np.float32)
            np.testing.assert_allclose(backend._host_eval(g),
                                       hostsim.sphere(g), rtol=1e-6)
            assert backend.stats["lease_requeues"] == 0

    def test_unresolvable_fitness_fails_fast_not_hangs(self, tmp_path):
        """A fleet whose workers cannot resolve the fitness (typo'd
        import spec) dies before claiming anything — since the straggler
        clock only starts at first claim, this must surface as a
        ChunkFailure, not an unbounded wait."""
        with QueueBackend(fn_spec="repro.fitness.hostsim:no_such_fn",
                          num_workers=2,
                          worker_pool=LocalWorkerPool(
                              num_workers=2, mode="thread", lease_s=5.0,
                              poll_s=0.005),
                          max_retries=1, mq_dir=str(tmp_path),
                          **FAST) as backend:
            with pytest.raises(ChunkFailure,
                               match="resolve the fitness"):
                backend._host_eval(np.ones((6, 2), np.float32))

    def test_failing_chunk_exhausts_retries(self, tmp_path):
        with QueueBackend(fn_spec="repro.fitness.hostsim:always_fail",
                          num_workers=2, worker_pool=_thread_pool(2),
                          max_retries=1, mq_dir=str(tmp_path),
                          **FAST) as backend:
            with pytest.raises(ChunkFailure, match="simulated simulator"):
                backend._host_eval(np.ones((6, 2), np.float32))
            assert backend.stats["retries"] == 1

    def test_claim_is_exclusive(self, tmp_path):
        """Two racing claimers: the atomic rename hands each ready task
        to exactly one of them."""
        mq = str(tmp_path)
        for d in (TASKS_DIR, CLAIMED_DIR, RESULTS_DIR):
            os.makedirs(os.path.join(mq, d))
        names = [task_name("a", 0, i, 0, 0) for i in range(8)]
        for n in names:
            with open(os.path.join(mq, TASKS_DIR, n), "wb") as f:
                f.write(b"x")
        claims: list = []
        lock = threading.Lock()

        def grab():
            while True:
                name = claim_next(mq)
                if name is None:
                    return
                with lock:
                    claims.append(name)

        threads = [threading.Thread(target=grab) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert sorted(claims) == sorted(names)   # each task exactly once


# ---------------------------------------------------------------------------
# streaming results: the EMA learns mid-flight, not at batch end
# ---------------------------------------------------------------------------

def test_cost_ema_observes_before_final_chunk_completes(tmp_path):
    release = threading.Event()

    def gated(genomes):
        g = np.asarray(genomes, np.float32)
        if bool(np.any(g[:, 0] > 0)):            # the designated straggler
            release.wait(timeout=30)
        return hostsim.sphere(g)

    ema = CostEMA(alpha=0.5)
    pool = _thread_pool(2, fn=gated)
    backend = QueueBackend(gated, num_workers=2, worker_pool=pool,
                           cost_ema=ema, mq_dir=str(tmp_path), **FAST)
    broker = Broker(cost_fn=ema, num_workers=2, backend=backend)
    g = np.full((8, 3), -1.0, np.float32)
    g[3, 0] = 1.0                                # exactly one hot genome
    gj = jnp.asarray(g)
    box = {}

    def run():
        box["fit"] = np.asarray(jax.jit(broker.evaluate)(gj)[0])

    t = threading.Thread(target=run, daemon=True)
    t.start()
    deadline = time.monotonic() + 20
    while ema.updates < 1 and time.monotonic() < deadline:
        time.sleep(0.005)
    try:
        # mid-flight: the fast chunk's duration reached the EMA while the
        # gated chunk is still running — batch-end observation would see
        # zero updates here
        assert ema.updates >= 1
        assert t.is_alive()
    finally:
        release.set()
    t.join(timeout=30)
    assert not t.is_alive()
    np.testing.assert_allclose(box["fit"], hostsim.sphere(g), rtol=1e-6)
    assert backend.stats["streamed"] >= 2        # both chunks streamed
    backend.close()


# ---------------------------------------------------------------------------
# broker-directory GC (bounded over long runs; stale leases reaped)
# ---------------------------------------------------------------------------

class TestBrokerGC:
    def test_ten_eval_run_leaves_bounded_directory(self, tmp_path):
        """Acceptance: a 10-eval mq run leaves a bounded broker directory
        — completed jobs reduce to their winning results and old jobs are
        swept beyond keep_jobs."""
        with QueueBackend(fn_spec=SPEC, num_workers=2, keep_jobs=3,
                          run_id="gc-run",
                          worker_pool=_thread_pool(2),
                          mq_dir=str(tmp_path), **FAST) as backend:
            g = np.ones((10, 3), np.float32)
            for _ in range(10):
                backend._host_eval(g)
            assert backend.stats["jobs"] == 10
            assert backend.stats["jobs_pruned"] == 7
            assert glob.glob(str(tmp_path / TASKS_DIR / "*")) == []
            assert glob.glob(str(tmp_path / CLAIMED_DIR / "*")) == []
            results = [os.path.basename(p) for p in
                       glob.glob(str(tmp_path / RESULTS_DIR / "*"))]
            # winning results of the newest keep_jobs jobs only: 2 chunks
            # per job, jobs 7..9 — all in this run's namespace
            assert len(results) == 6
            parsed = [parse_task_name(r[:-len(".result.npz")] + ".npz")
                      for r in results]
            assert {p[0] for p in parsed} == {"gc-run"}
            assert {p[1] for p in parsed} == {7, 8, 9}

    def test_orphan_claims_and_leases_reaped(self, tmp_path):
        """Claimed files + lease files left by killed workers are swept
        with their job (the lease-requeue path already reclaims live
        jobs; this is the epilogue for whatever remains)."""
        mq = str(tmp_path)
        with QueueBackend(fn_spec=SPEC, num_workers=2, keep_jobs=4,
                          worker_pool=_thread_pool(2), mq_dir=mq,
                          **FAST) as backend:
            # a worker killed mid-task in job 0 left its claim + lease
            orphan = task_name(backend.run_id, 0, 99, 0, 0)
            for path in (os.path.join(mq, CLAIMED_DIR, orphan),
                         os.path.join(mq, CLAIMED_DIR,
                                      orphan + LEASE_SUFFIX)):
                with open(path, "w") as f:
                    f.write("orphan")
            backend._host_eval(np.ones((6, 2), np.float32))   # job 0
            leftovers = os.listdir(os.path.join(mq, CLAIMED_DIR))
            assert leftovers == []

    def test_requeued_duplicate_results_are_swept(self, tmp_path):
        """At-least-once delivery can produce duplicate results (the
        re-queued delivery races the original); job GC keeps exactly one
        winner per chunk."""
        pool = _thread_pool(2, lease_s=0.4,
                            hang_substrings=("c0001_t0_d0",))
        with QueueBackend(fn_spec=SPEC, num_workers=2, worker_pool=pool,
                          lease_s=0.4, keep_jobs=4, chunk_timeout_s=30,
                          poll_interval_s=0.005,
                          mq_dir=str(tmp_path)) as backend:
            backend._host_eval(np.ones((8, 3), np.float32))
            results = sorted(os.path.basename(p) for p in
                             glob.glob(str(tmp_path / RESULTS_DIR / "*")))
            chunks = {r.split("_t")[0] for r in results}
            assert len(results) == len(chunks) == 2   # one winner each
            assert all(r.endswith(".result.npz") for r in results)


# ---------------------------------------------------------------------------
# worker-side folding of sub-startup-cost chunks (integration; the size
# invariants are property-tested next to the other chunking laws in
# test_batchq.py)
# ---------------------------------------------------------------------------

def test_min_chunk_cost_folds_tiny_chunks_in_dispatch(tmp_path):
    n, w = 12, 4
    cost = np.where(np.arange(n) < 2, 10.0, 0.1)
    expected = len(cost_sized_chunk_sizes(
        np.sort(cost)[::-1], w, min_chunk_cost=1.5))
    assert expected < w                          # the floor actually folds
    with QueueBackend(fn_spec=SPEC, num_workers=w, keep_jobs=1,
                      min_chunk_cost_s=1.5,
                      worker_pool=_thread_pool(2),
                      mq_dir=str(tmp_path), **FAST) as backend:
        broker = Broker(cost_fn=lambda g: jnp.asarray(cost, jnp.float32),
                        num_workers=w, backend=backend)
        g = jax.random.uniform(jax.random.PRNGKey(5), (n, 3))
        fit, _ = jax.jit(broker.evaluate)(g)
        np.testing.assert_allclose(np.asarray(fit), np.asarray(sphere(g)),
                                   rtol=1e-6)
        results = glob.glob(str(tmp_path / RESULTS_DIR / "*.result.npz"))
        assert len(results) == expected          # folded chunk count


# ---------------------------------------------------------------------------
# drain-before-close (the pipelined epoch loop can still have a
# pure_callback polling the queue when the backend is torn down)
# ---------------------------------------------------------------------------

def test_close_drains_inflight_then_stops_workers(tmp_path):
    def slow(genomes):
        time.sleep(0.3)
        return hostsim.sphere(np.asarray(genomes))

    pool = _thread_pool(2, fn=slow)
    backend = QueueBackend(slow, num_workers=2, worker_pool=pool,
                           mq_dir=str(tmp_path), **FAST)
    g = np.random.default_rng(7).uniform(-1, 1, (6, 3)).astype(np.float32)
    box = {}
    t = threading.Thread(
        target=lambda: box.update(out=backend._host_eval(g)), daemon=True)
    t.start()
    time.sleep(0.05)                             # eval is in flight
    backend.close()                              # must drain, not strand
    t.join(timeout=30)
    assert not t.is_alive()
    np.testing.assert_allclose(box["out"], hostsim.sphere(g), rtol=1e-6)
    # closed: the STOP sentinel is up and further use is an error
    assert os.path.exists(str(tmp_path / STOP_NAME))
    with pytest.raises(RuntimeError, match="after close"):
        backend._host_eval(g)


def test_worker_loop_exits_on_stop_and_max_tasks(tmp_path):
    mq = str(tmp_path)
    for d in (TASKS_DIR, CLAIMED_DIR, RESULTS_DIR):
        os.makedirs(os.path.join(mq, d))
    from repro.runtime.fsatomic import atomic_savez
    for i in range(3):
        atomic_savez(os.path.join(mq, TASKS_DIR, task_name("a", 0, i, 0, 0)),
                      genomes=np.ones((2, 2), np.float32))
    done = worker_loop(mq, fn=hostsim.sphere, max_tasks=2, poll_s=0.005)
    assert done == 2
    with open(os.path.join(mq, STOP_NAME), "w") as f:
        f.write("stop")
    assert worker_loop(mq, fn=hostsim.sphere, poll_s=0.005) == 0


# ---------------------------------------------------------------------------
# elastic fleet autoscaling (queue-depth scale-up, poison-ticket
# scale-down at chunk boundaries)
# ---------------------------------------------------------------------------

class TestAutoscaler:
    def test_poison_ticket_honored_at_chunk_boundary(self, tmp_path):
        """A worker claims a poison STOP ticket only when no real task is
        ready — queued work always drains first — and exits cleanly,
        removing the ticket."""
        mq = str(tmp_path)
        make_broker_dirs(mq)
        from repro.runtime.fsatomic import atomic_savez
        for i in range(2):
            atomic_savez(os.path.join(mq, TASKS_DIR,
                                       task_name("a", 0, i, 0, 0)),
                          genomes=np.ones((2, 2), np.float32))
        with open(os.path.join(mq, TASKS_DIR, "zzzstop-0"
                               + POISON_SUFFIX), "w") as f:
            f.write("stop")
        done = worker_loop(mq, fn=hostsim.sphere, poll_s=0.005)
        assert done == 2                         # both chunks before exit
        assert os.listdir(os.path.join(mq, TASKS_DIR)) == []
        assert os.listdir(os.path.join(mq, CLAIMED_DIR)) == []
        results = os.listdir(os.path.join(mq, RESULTS_DIR))
        assert len(results) == 2

    def test_autoscaler_replaces_crashed_workers(self, tmp_path):
        """The controller reconciles its intended size with the pool's
        live count: a worker that CRASHED (not poison-retired) leaves
        size overstating the fleet, and the next tick must re-grow
        toward the backlog instead of starving on ghosts."""
        mq = str(tmp_path)
        make_broker_dirs(mq)
        from repro.runtime.fsatomic import atomic_savez
        for i in range(2):                       # backlog of 2 ready tasks
            atomic_savez(os.path.join(mq, TASKS_DIR,
                                       task_name("a", 0, i, 0, 0)),
                          genomes=np.ones((2, 2), np.float32))

        class GhostPool:
            """3 intended workers, 1 actually alive."""
            num_workers = 3
            mq_dir = mq
            grown = []

            def alive_workers(self):
                return 1

            def grow(self, n):
                self.grown.append(n)

        pool = GhostPool()
        scaler = FleetAutoscaler(pool, min_workers=1, max_workers=4,
                                 cooldown_s=0.0)
        scaler.mq_dir = mq
        scaler.size = 3                          # stale intended size
        scaler._tick(time.monotonic())
        # reconciled 3 -> 1 alive, then grew toward the 2-task backlog
        assert pool.grown == [1]
        assert scaler.size == 2

    def test_autoscaler_grows_on_depth_and_shrinks_on_drain(self,
                                                            tmp_path):
        """Acceptance: a deep queue on a 1-worker floor makes the
        controller grow the fleet (incremental pool submit); once the
        queue drains it shrinks back to min_workers via poison tickets
        that idle workers consume."""
        def slow(genomes):
            time.sleep(0.12)
            return hostsim.sphere(np.asarray(genomes))

        pool = LocalWorkerPool(num_workers=1, mode="thread", fn=slow,
                               lease_s=30.0, poll_s=0.005)
        scaler = FleetAutoscaler(pool, min_workers=1, max_workers=4,
                                 interval_s=0.02, cooldown_s=0.02)
        with QueueBackend(slow, num_workers=8, worker_pool=pool,
                          autoscaler=scaler, mq_dir=str(tmp_path),
                          **FAST) as backend:
            g = np.random.default_rng(11).uniform(
                -1, 1, (16, 3)).astype(np.float32)
            out = backend._host_eval(g)          # 8 chunks, 1 worker floor
            np.testing.assert_allclose(out, hostsim.sphere(g), rtol=1e-6)
            assert scaler.stats["scale_ups"] >= 1
            assert scaler.stats["peak_workers"] >= 2
            assert pool.num_workers >= 2
            # drain: the controller shrinks to the floor and idle workers
            # retire on the poison tickets (>= timing tolerance: poll)
            deadline = time.monotonic() + 15
            while ((scaler.size > 1 or pool.alive_workers() > 1)
                   and time.monotonic() < deadline):
                time.sleep(0.02)
            assert scaler.size == 1
            assert scaler.stats["scale_downs"] >= 1
            assert pool.alive_workers() <= 1


# ---------------------------------------------------------------------------
# ga_run end-to-end on the mq-mock backend (acceptance: bit-identical best
# fitness to InlineBackend on the same seed, bounded broker directory)
# ---------------------------------------------------------------------------

def test_ga_run_mq_mock_e2e_bit_identical_to_inline(tmp_path):
    from repro.launch.ga_run import main
    common = ["--fitness", "sphere", "--genes", "1", "--islands", "2",
              "--pop", "8", "--epochs", "2", "--gens-per-epoch", "2",
              "--seed", "3"]
    pop_inline, hist_inline = main(common)
    pop_mq, hist_mq = main(common + [
        "--dispatch-backend", "mq-mock", "--chunk-timeout-s", "60",
        "--keep-jobs", "2", "--lease-s", "30",
        "--mq-dir", str(tmp_path / "mq")])
    assert len(hist_mq) == len(hist_inline) == 2
    # bit-identical trajectory: same fitness bits, same genomes, same best
    assert np.array_equal(np.asarray(pop_inline.fitness),
                          np.asarray(pop_mq.fitness))
    assert np.array_equal(np.asarray(pop_inline.genomes),
                          np.asarray(pop_mq.genomes))
    # broker-directory GC held under the full engine loop
    results = [os.path.basename(p) for p in
               glob.glob(str(tmp_path / "mq" / RESULTS_DIR / "*"))]
    jobs = {parse_task_name(r[:-len(".result.npz")] + ".npz")[1]
            for r in results}
    assert len(jobs) <= 2
    assert glob.glob(str(tmp_path / "mq" / TASKS_DIR / "*")) == []


def test_ga_run_remote_fleet_requires_shared_mq_dir():
    """--mq-fleet slurm|k8s with the default temp broker dir would leave
    the cluster fleet idling on a path it cannot see — rejected up
    front."""
    from repro.launch.ga_run import main
    with pytest.raises(SystemExit):
        main(["--fitness", "sphere", "--dispatch-backend", "mq",
              "--mq-fleet", "slurm"])
