"""Thread sanitizer (repro.analysis.sanitize): seeded-race fixtures the
detector MUST flag (and their synchronized twins it must not), seed →
identical-schedule determinism, the real-runtime scenarios race-clean,
lock-stripped negative controls pinning each PR-8 runtime fix, the FS
fault-injection sweep, and the janitor's torn-tmp coverage outside the
queue dirs."""
import os
import tempfile
import threading

import numpy as np
import pytest

from repro.analysis.sanitize import (Tracer, detect_races, format_report,
                                     instrumented, track_attrs, track_dict,
                                     track_list)
from repro.analysis.sanitize.faultinject import fault_sweep
from repro.analysis.sanitize.schedfuzz import PCTScheduler
from repro.analysis.sanitize.scenarios import (SCENARIOS, _fault_scenario,
                                               run_scenario, run_sanitize)

_REAL_LOCK = threading.Lock   # pre-patch: invisible to the tracer


def sites(races):
    return {s for r in races for s in (r.a.site, r.b.site)}


# ---------------------------------------------------------------------------
# Seeded-race fixtures: each MUST be detected; each synchronized twin
# MUST be clean
# ---------------------------------------------------------------------------

class TestSeededRaces:
    def test_unlocked_counter_detected(self):
        tracer = Tracer()
        with instrumented(tracer):
            stats = track_dict({"n": 0}, "stats", tracer)

            def bump():
                for _ in range(20):
                    stats["n"] = stats["n"] + 1

            ts = [threading.Thread(target=bump) for _ in range(2)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        races = detect_races(tracer.events)
        assert races, "unlocked counter must race"
        assert any(r.var == "stats['n']" for r in races)
        report = format_report(races)
        assert "RACE stats['n']" in report and "↔" in report

    def test_locked_counter_clean(self):
        tracer = Tracer()
        with instrumented(tracer):
            lock = threading.Lock()
            stats = track_dict({"n": 0}, "stats", tracer)

            def bump():
                for _ in range(20):
                    with lock:
                        stats["n"] = stats["n"] + 1

            ts = [threading.Thread(target=bump) for _ in range(2)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        assert detect_races(tracer.events) == []

    def test_lockset_disjoint_pair_detected(self):
        """Each side holds A lock — just not the SAME lock. Lock
        release→acquire is deliberately NOT a happens-before edge here
        (hybrid-detector style), so even when the schedule happens not
        to overlap the accesses, disjoint locksets still convict."""
        tracer = Tracer()
        with instrumented(tracer):
            la, lb = threading.Lock(), threading.Lock()
            shared = track_dict({"x": 0}, "shared", tracer)

            def via(lk):
                with lk:
                    shared["x"] = shared["x"] + 1

            t1 = threading.Thread(target=via, args=(la,))
            t2 = threading.Thread(target=via, args=(lb,))
            t1.start()
            t2.start()
            t1.join()
            t2.join()
        races = detect_races(tracer.events)
        assert any(r.var == "shared['x']" for r in races)
        assert "∩" in format_report(races)

    def test_common_lock_one_of_many_clean(self):
        tracer = Tracer()
        with instrumented(tracer):
            common, extra = threading.Lock(), threading.Lock()
            shared = track_dict({"x": 0}, "shared", tracer)

            def a():
                with common:
                    shared["x"] = 1

            def b():
                with extra:
                    with common:
                        shared["x"] = 2

            t1, t2 = threading.Thread(target=a), threading.Thread(target=b)
            t1.start()
            t2.start()
            t1.join()
            t2.join()
        assert detect_races(tracer.events) == []

    def test_missed_join_publish_detected(self):
        tracer = Tracer()
        with instrumented(tracer):
            out = track_dict({}, "out", tracer)

            def produce():
                out["result"] = 42

            t = threading.Thread(target=produce)
            t.start()
            _ = out.get("result")      # read BEFORE the join
            t.join()
        races = detect_races(tracer.events)
        assert any(r.var == "out['result']" for r in races)

    def test_join_establishes_order_clean(self):
        tracer = Tracer()
        with instrumented(tracer):
            out = track_dict({}, "out", tracer)

            def produce():
                out["result"] = 42

            t = threading.Thread(target=produce)
            t.start()
            t.join()
            _ = out.get("result")
        assert detect_races(tracer.events) == []

    def test_fork_publishes_parent_writes(self):
        """Parent writes before start() are visible to the child."""
        tracer = Tracer()
        with instrumented(tracer):
            box = track_dict({}, "box", tracer)
            box["cfg"] = 1

            def consume():
                _ = box.get("cfg")

            t = threading.Thread(target=consume)
            t.start()
            t.join()
        assert detect_races(tracer.events) == []

    def test_condition_notify_orders_handoff(self):
        tracer = Tracer()
        with instrumented(tracer):
            cond = threading.Condition()
            box = track_dict({}, "box", tracer)

            def produce():
                with cond:
                    box["v"] = 7
                    cond.notify_all()

            t = threading.Thread(target=produce)
            with cond:
                t.start()
                cond.wait(5.0)
            with cond:
                _ = box.get("v")
            t.join()
        assert detect_races(tracer.events) == []

    def test_tracked_list_and_attrs(self):
        tracer = Tracer()
        with instrumented(tracer):
            class Box:
                pass

            b = Box()
            b.size = 0
            track_attrs(b, "Box", tracer, ["size"])
            members = track_list([], "members", tracer)

            def grow():
                members.append(1)
                b.size = b.size + 1

            ts = [threading.Thread(target=grow) for _ in range(2)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        races = detect_races(tracer.events)
        assert any(r.var == "members" for r in races)
        assert any(r.var == "Box.size" for r in races)


# ---------------------------------------------------------------------------
# Determinism: a seed names one schedule
# ---------------------------------------------------------------------------

class TestDeterminism:
    @staticmethod
    def _one(seed):
        tracer = Tracer()
        sched = PCTScheduler(seed, wall_s=45.0)
        with instrumented(tracer, scheduler=sched):
            stats = track_dict({"n": 0}, "stats", tracer)

            def bump():
                for _ in range(5):
                    stats["n"] = stats["n"] + 1

            ts = [threading.Thread(target=bump) for _ in range(3)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            sched.open_freerun()
        assert not sched.truncated
        trace = [(e.tid, e.kind, e.obj, e.site) for e in tracer.events]
        return trace, format_report(detect_races(tracer.events))

    def test_same_seed_identical_trace_and_report(self):
        t1, r1 = self._one(7)
        t2, r2 = self._one(7)
        assert t1 == t2
        assert r1 == r2
        assert "RACE" in r1        # the fixture really races

    def test_different_seed_different_schedule(self):
        t1, _ = self._one(7)
        t3, _ = self._one(8)
        assert t1 != t3


# ---------------------------------------------------------------------------
# Real-runtime scenarios: race-clean after the PR-8 fixes
# ---------------------------------------------------------------------------

class TestScenariosClean:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_scenario_race_clean(self, name):
        r = run_scenario(name, seed=0, wall_s=45.0)
        assert r.error is None, r.error
        assert r.races == [], format_report(r.races)
        assert r.events > 0

    def test_driver_exit_clean(self, capsys):
        assert run_sanitize(seed=0, schedules=1, wall_s=45.0,
                            fault_inject=False) == 0
        assert "run(s) explored" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Seed-pinned regressions: strip one fix's lock → the sanitizer must
# light up; the shipped (locked) code must stay dark
# ---------------------------------------------------------------------------

SEED_AUTOSCALER = 5     # pinned: this seed exhibits the stripped race


class TestFixRegressions:
    def _autoscaler(self, strip):
        from repro.analysis.sanitize.scenarios import mq_autoscaler
        import repro.runtime.mq as mq

        orig_init = mq.FleetAutoscaler.__init__
        if strip:
            def unlocked_init(self, *a, **kw):
                orig_init(self, *a, **kw)
                self._lock = _REAL_LOCK()   # invisible = pre-fix
            mq.FleetAutoscaler.__init__ = unlocked_init
        try:
            tracer = Tracer()
            sched = PCTScheduler(SEED_AUTOSCALER, wall_s=45.0)
            with instrumented(tracer, sched):
                cleanup = mq_autoscaler(tracer)
                sched.open_freerun()
                cleanup()
            return detect_races(tracer.events)
        finally:
            mq.FleetAutoscaler.__init__ = orig_init

    def test_autoscaler_tick_lock_regression(self):
        assert self._autoscaler(strip=False) == []
        races = self._autoscaler(strip=True)
        assert any("FleetAutoscaler" in r.var for r in races), \
            "stripping the autoscaler lock must surface the tick races"
        assert any("mq.py" in s for s in sites(races))

    def _pool(self, strip, tmp_path):
        from repro.runtime.mq import LocalWorkerPool

        tracer = Tracer()
        sched = PCTScheduler(3, wall_s=45.0)
        with instrumented(tracer, sched):
            pool = LocalWorkerPool(1, "thread", mq_dir=str(tmp_path),
                                   fn=lambda g: g.sum(1, keepdims=True),
                                   lease_s=30.0, poll_s=0.001)
            if strip:
                pool._lock = _REAL_LOCK()
            pool._members = track_list(pool._members,
                                       "LocalWorkerPool._members", tracer)
            pool.start()

            def grower():
                pool.grow(1)

            g = threading.Thread(target=grower)
            g.start()
            pool.alive_workers()
            g.join()
            sched.open_freerun()
            pool.stop()
        return detect_races(tracer.events)

    def test_worker_pool_members_lock_regression(self, tmp_path):
        assert self._pool(False, tmp_path / "a") == []
        races = self._pool(True, tmp_path / "b")
        assert any(r.var == "LocalWorkerPool._members" for r in races), \
            "stripping the pool lock must surface the members race"

    class _StubScheduler:
        def submit(self, tickets, job_dir=None):
            return [f"h{t}" for t in tickets]

        def poll(self, handle):
            return "done"

        def cancel(self, handle):
            pass

    def _fleet(self, strip, tmp_path):
        from repro.runtime.mq import MQWorkerFleet

        tracer = Tracer()
        sched = PCTScheduler(3, wall_s=45.0)
        with instrumented(tracer, sched):
            fleet = MQWorkerFleet(self._StubScheduler(), 1,
                                  mq_dir=str(tmp_path))
            if strip:
                fleet._lock = _REAL_LOCK()
            fleet.handles = track_list(fleet.handles,
                                       "MQWorkerFleet.handles", tracer)
            track_attrs(fleet, "MQWorkerFleet", tracer,
                        ["_ticket_seq", "num_workers"])
            fleet.start()

            def grower():
                fleet.grow(1)

            g = threading.Thread(target=grower)
            g.start()
            fleet.alive_workers()
            g.join()
            sched.open_freerun()
            fleet.stop(timeout_s=0.1)
        return detect_races(tracer.events)

    def test_fleet_tickets_lock_regression(self, tmp_path):
        assert self._fleet(False, tmp_path / "a") == []
        races = self._fleet(True, tmp_path / "b")
        assert any(r.var in ("MQWorkerFleet.handles",
                             "MQWorkerFleet._ticket_seq",
                             "MQWorkerFleet.num_workers")
                   for r in races), \
            "stripping the fleet lock must surface the submit races"

    def test_priority_cache_locked(self, tmp_path):
        """run_priority's cache writes go through _PRIORITY_LOCK (the
        pre-fix bare dict mutation pattern must be gone)."""
        from repro.runtime import mq

        mq_dir = str(tmp_path)
        mq.make_broker_dirs(mq_dir)
        mq.register_run(mq_dir, "prio", priority=3,
                        fn_spec="tests.conftest:_nope", num_objectives=1)
        tracer = Tracer()
        with instrumented(tracer):
            old = (mq._PRIORITY_CACHE, mq._PRIORITY_LOCK)
            # the tracked twin of the module pair: an instrumented lock
            # (the module-level one predates the context, so the tracer
            # cannot see it) guarding a tracked cache
            mq._PRIORITY_CACHE = track_dict(dict(mq._PRIORITY_CACHE),
                                            "_PRIORITY_CACHE", tracer)
            mq._PRIORITY_LOCK = threading.Lock()
            try:
                ts = [threading.Thread(
                    target=lambda: mq.run_priority(mq_dir, "prio"))
                    for _ in range(2)]
                for t in ts:
                    t.start()
                for t in ts:
                    t.join()
            finally:
                mq._PRIORITY_CACHE, mq._PRIORITY_LOCK = old
        assert detect_races(tracer.events) == [], \
            "run_priority cache accesses must share _PRIORITY_LOCK"


# ---------------------------------------------------------------------------
# FS fault injection on the real broker tree
# ---------------------------------------------------------------------------

class TestFaultInjection:
    def test_sweep_clean_and_covers_publish_sites(self):
        logs = []
        res = fault_sweep(
            _fault_scenario,
            lambda: tempfile.mkdtemp(prefix="san-test-fault-"),
            log=logs.append)
        assert res.ok, res.problems
        ops = {s.split("@")[0] for s in res.sites}
        assert "publish" in ops, res.sites
        assert res.passes == len(res.sites) > 0
        assert any("fired" in line for line in logs)

    def test_janitor_reaps_torn_tmp_outside_queue_dirs(self, tmp_path):
        """The gap this PR's sweep found: crashed publishers of registry
        entries, fleet tickets, and the STOP sentinel leave *.tmp where
        the janitor never looked."""
        from repro.runtime.mq import (FLEET_DIR, RUNS_DIR, janitor_sweep,
                                      make_broker_dirs)

        mq_dir = str(tmp_path)
        make_broker_dirs(mq_dir)
        os.makedirs(os.path.join(mq_dir, FLEET_DIR), exist_ok=True)
        torn = [os.path.join(mq_dir, RUNS_DIR, "r1.json.tmp"),
                os.path.join(mq_dir, FLEET_DIR, "w0.worker.json.tmp"),
                os.path.join(mq_dir, "STOP.tmp")]
        for path in torn:
            with open(path, "w") as f:
                f.write("torn")
        # age guard still protects in-flight writes
        assert janitor_sweep(mq_dir, max_age_s=9999.0) == 0
        assert all(os.path.exists(p) for p in torn)
        assert janitor_sweep(mq_dir, max_age_s=-1.0) >= len(torn)
        assert not any(os.path.exists(p) for p in torn)


# ---------------------------------------------------------------------------
# Zero-cost-when-disabled: the runtime never imports the sanitizer
# ---------------------------------------------------------------------------

def test_runtime_does_not_import_sanitizer():
    import subprocess
    import sys
    code = ("import sys, repro.runtime.mq, repro.runtime.batchq, "
            "repro.core.broker; "
            "bad = [m for m in sys.modules if 'sanitize' in m]; "
            "assert not bad, bad; print('clean')")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    assert out.returncode == 0 and "clean" in out.stdout, out.stderr


def test_instrumented_restores_factories():
    before = (threading.Lock, threading.RLock, threading.Condition,
              threading.Event, threading.Thread)
    tracer = Tracer()
    with instrumented(tracer):
        assert threading.Lock is not before[0]
    after = (threading.Lock, threading.RLock, threading.Condition,
             threading.Event, threading.Thread)
    assert before == after
