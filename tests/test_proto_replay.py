"""Model-derived adversarial schedules replayed against the REAL mq.

The model checker (repro.analysis.proto) explores the broker contract
over an abstraction; these tests drive its worst interleavings through
the real ``runtime/mq.py`` code paths thread-by-thread with a
step-barrier (``QueueBackend(step_hook=...)`` + the extracted worker
protocol helpers), as deterministic tier-1 regressions. Every schedule
here replays a counterexample trace the explorer produced against the
pre-fix protocol (or the good-spec race the contract clause is about).

Every replay is parametrized over BOTH broker transports: the file
broker (protocol functions against a shared directory) and the socket
broker (the same steps as RPC frames against a ``BrokerServer``, via
``Replayer(client=...)``). Bit-identical behavior across the corpus —
same accepted fitness, same stats counters, same leftovers — is the
transport-swap acceptance criterion.
"""
import os
import threading

import numpy as np
import pytest

from repro.analysis.proto import schedules as sched
from repro.analysis.proto.explorer import explore
from repro.analysis.proto.replay import Replayer, StepGate, to_replay_steps
from repro.analysis.proto.spec import SpecConfig
from repro.fitness import hostsim
from repro.runtime.mq import (CLAIMED_DIR, RESULTS_DIR, TASKS_DIR,
                              QueueBackend, result_name)
from repro.runtime.netbroker import (BrokerClient, BrokerServer,
                                     SocketQueueBackend)

SPEC = "repro.fitness.hostsim:sphere"

TRANSPORTS = ("file", "net")


def _ra_files(mq_dir):
    out = []
    for d in (TASKS_DIR, CLAIMED_DIR, RESULTS_DIR):
        out += [f"{d}/{n}" for n in os.listdir(os.path.join(mq_dir, d))
                if n.startswith("ra_")]
    return sorted(out)


class _Run:
    """One gated manager evaluation: backend + manager thread + replayer.

    ``lease_s=60`` means a lease can only go stale through the
    schedule's explicit ``env.expire`` backdating — wall-clock time
    cannot perturb the interleaving, which is what makes replay
    deterministic. ``transport="net"`` swaps in a ``BrokerServer`` +
    ``SocketQueueBackend`` and reroutes every replay step through RPC
    frames; the assertions stay byte-for-byte the same."""

    def __init__(self, tmp_path, transport="file", n=4, num_workers=2,
                 **kw):
        self.gate = StepGate()
        self.transport = transport
        kw.setdefault("keep_jobs", 4)
        common = dict(fn_spec=SPEC, num_workers=num_workers, run_id="a",
                      lease_s=60.0, chunk_timeout_s=None, max_retries=0,
                      poll_interval_s=0.005, step_hook=self.gate.step)
        if transport == "file":
            self.mq_dir = str(tmp_path)
            self.server = self.probe = None
            self.qb = QueueBackend(mq_dir=self.mq_dir, **common, **kw)
            self.replayer = Replayer(self.mq_dir, hostsim.sphere,
                                     lease_s=60.0)
        else:
            self.server = BrokerServer().start()
            self.mq_dir = None
            self.qb = SocketQueueBackend(server=self.server, **common,
                                         **kw)
            self.probe = BrokerClient(self.server.addr)
            self.replayer = Replayer(None, hostsim.sphere, lease_s=60.0,
                                     client=self.probe)
        self.g = np.random.default_rng(0).uniform(
            -1, 1, (n, 3)).astype(np.float32)
        self.out = {}

        def manager():
            try:
                self.out["fit"] = self.qb._host_eval(self.g)
            except Exception as exc:          # surfaced by the test body
                self.out["exc"] = exc
            finally:
                self.gate.finish()

        self.thread = threading.Thread(target=manager, daemon=True)
        self.thread.start()

    def ra_files(self):
        """This run's files across the queue dirs — via listdir on the
        file broker, via the LIST debug op on the socket broker."""
        if self.transport == "file":
            return _ra_files(self.mq_dir)
        listing = self.probe.listdir()
        return sorted(f"{d}/{n}" for d in ("tasks", "claimed", "results")
                      for n in listing[d] if n.startswith("ra_"))

    def result_exists(self, task):
        return f"results/{result_name(task)}" in self.ra_files()

    def replay(self, steps):
        self.replayer.run(self.gate, steps)

    def finish(self):
        """Free-run the manager to completion and return its fitness."""
        self.gate.open()
        self.thread.join(timeout=60)
        assert not self.thread.is_alive(), "manager never finished"
        if "exc" in self.out:
            raise self.out["exc"]
        return self.out["fit"]

    def shutdown(self):
        self.gate.open()
        self.qb.close()                       # idempotent
        if self.probe is not None:
            self.probe.close()
        if self.server is not None:
            self.server.stop()


@pytest.fixture(params=TRANSPORTS)
def make_run(request, tmp_path):
    runs = []

    def factory(n=4, num_workers=2, **kw):
        run = _Run(tmp_path, transport=request.param, n=n,
                   num_workers=num_workers, **kw)
        runs.append(run)
        return run

    yield factory
    for run in runs:
        run.shutdown()


def test_stale_lease_requeue_first_result_wins(make_run):
    """Delivery 1 answers a re-queued chunk; the superseded delivery 0
    then lands a CONFLICTING value. First-result-wins: the accepted
    fitness is delivery 1's, and the conflict is swept with the job."""
    run = make_run()
    run.replay(sched.stale_lease_requeue_conflicting_late_publish())
    fit = run.finish()
    np.testing.assert_allclose(
        fit.reshape(hostsim.sphere(run.g).shape), hostsim.sphere(run.g),
        rtol=1e-6)
    assert not np.any(fit >= 1e8), "conflicting superseded result accepted"
    assert run.qb.stats["lease_requeues"] == 1
    assert run.qb.stats["retries"] == 0, \
        "a lease re-queue burned the retry budget"
    run.qb.close()
    assert run.ra_files() == []


def test_crash_after_publish_result_accepted_orphan_reaped(make_run):
    """A worker killed between publish and release: the chunk is not
    lost (its published result is accepted) and the job epilogue GC
    reaps the dead worker's orphan claim + lease."""
    run = make_run()
    run.replay(sched.crash_after_publish_orphan_claim())
    fit = run.finish()
    np.testing.assert_allclose(
        fit.reshape(hostsim.sphere(run.g).shape), hostsim.sphere(run.g),
        rtol=1e-6)
    # the orphan claim/lease of job 0 are gone (non-active job sweep)
    assert not [p for p in run.ra_files() if p.startswith("claimed/")]
    run.qb.close()
    assert run.ra_files() == []


def test_torn_publish_never_read_and_janitor_reaps(make_run):
    """A publisher killed mid-atomic-write leaves only the torn ``*.tmp``
    sibling: the manager must never read it (delivery 1 answers the
    chunk instead) and the janitor reaps the aged dropping."""
    run = make_run()
    run.replay(sched.torn_publish_invisible_then_reaped())
    fit = run.finish()
    np.testing.assert_allclose(
        fit.reshape(hostsim.sphere(run.g).shape), hostsim.sphere(run.g),
        rtol=1e-6)
    assert run.qb.stats["lease_requeues"] == 1
    run.qb.close()
    leftovers = run.ra_files()
    assert not [p for p in leftovers if p.endswith(".tmp")], leftovers
    assert leftovers == []


def test_late_publish_after_close_tombstone_prevents_leak(make_run):
    """THE model-checker counterexample (no_tombstone variant): a
    superseded delivery publishes after ``close()`` already swept the
    run's namespace. Without ``clean_if_run_closed`` the result leaks
    forever in a shared broker dir; the tombstone removes it."""
    run = make_run()
    run.replay(sched.late_publish_after_close_prefix())
    fit = run.finish()
    np.testing.assert_allclose(
        fit.reshape(hostsim.sphere(run.g).shape), hostsim.sphere(run.g),
        rtol=1e-6)
    run.qb.close()
    assert run.ra_files() == []                  # close swept everything
    # ...and only now does the slow worker land its superseded result
    suffix = sched.late_publish_after_close_suffix()
    run.replayer.worker_step(*suffix[0])         # w0.publish
    assert run.result_exists(sched.tname(0)), \
        "setup: the late publish must land"
    for step in suffix[1:]:                      # w0.release, w0.tombstone
        run.replayer.worker_step(*step)
    assert run.ra_files() == [], \
        "late publish after close leaked past the tombstone"


def test_explorer_counterexample_translates_and_replays(make_run):
    """Close the loop LIVE: run the explorer on the pre-fix protocol
    (``no_tombstone``), translate its minimal counterexample schedule
    with ``to_replay_steps``, and replay it against the real (fixed)
    mq — the real protocol must survive the exact interleaving that
    broke the unfixed model."""
    cfg = SpecConfig(chunks=1, max_crashes=0, variant="no_tombstone")
    result = explore(cfg, max_depth=60, max_states=200_000)
    assert not result.ok, "seeded-bad variant must produce a counterexample"
    assert "leak" in result.violation
    labels = result.schedule
    # split the trace at the close: the gated prefix replays against the
    # live manager, the suffix is the post-close leak
    cut = labels.index("m.close_dereg")
    prefix = to_replay_steps(labels[:cut])
    suffix = to_replay_steps(labels[cut:])
    assert prefix and suffix, (prefix, suffix)
    run = make_run(n=4, num_workers=1)           # 1 chunk, like the model
    run.replay(prefix)
    fit = run.finish()
    np.testing.assert_allclose(
        fit.reshape(hostsim.sphere(run.g).shape), hostsim.sphere(run.g),
        rtol=1e-6)
    run.qb.close()
    for step in suffix:
        if step[0] == "manager":
            continue                             # manager is closed
        if step[0] == "env":
            run.replayer.env_step(step[1], step[2] if len(step) > 2
                                  else None)
        else:
            run.replayer.worker_step(*step)
    assert run.ra_files() == [], \
        "the explorer's leak schedule leaked against the real mq"
