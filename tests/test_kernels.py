"""Pallas kernel allclose tests vs pure-jnp oracles (interpret mode on CPU)
with shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.attention import ops as attn_ops
from repro.kernels.attention.ref import dense_reference
from repro.kernels.genetic import ops as gen_ops
from repro.kernels.ssd import ops as ssd_ops
from repro.models.ssm import ssd_chunked_ref

RNG = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# fused genetic variation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p,g", [(16, 4), (64, 18), (130, 33), (256, 128)])
def test_genetic_kernel_matches_oracle(p, g):
    p -= p % 2
    parents = jax.random.uniform(RNG, (p, g), minval=-1, maxval=1)
    kw = dict(eta_cx=15.0, prob_cx=0.9, eta_mut=20.0, prob_mut=0.7,
              indpb=1.0 / g, lower=-1.0, upper=1.0)
    a = gen_ops.fused_variation(RNG, parents, **kw)
    b = gen_ops.fused_variation_oracle(RNG, parents, **kw)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(eta_cx=st.floats(1.0, 80.0), eta_mut=st.floats(1.0, 80.0),
       prob=st.floats(0.0, 1.0), seed=st.integers(0, 2**30))
def test_genetic_kernel_property(eta_cx, eta_mut, prob, seed):
    rng = jax.random.PRNGKey(seed)
    parents = jax.random.uniform(rng, (32, 9), minval=-2, maxval=2)
    kw = dict(eta_cx=eta_cx, prob_cx=prob, eta_mut=eta_mut, prob_mut=prob,
              indpb=0.4, lower=-2.0, upper=2.0)
    a = gen_ops.fused_variation(rng, parents, **kw)
    b = gen_ops.fused_variation_oracle(rng, parents, **kw)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)
    assert bool(jnp.all((a >= -2) & (a <= 2)))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, S, H, KV, hd, causal, window, softcap, dtype)
    (2, 256, 8, 4, 64, True, 0, 0.0, jnp.float32),
    (1, 200, 4, 4, 32, True, 50, 0.0, jnp.float32),
    (2, 128, 8, 2, 64, False, 0, 30.0, jnp.float32),
    (1, 384, 6, 2, 128, True, 100, 50.0, jnp.float32),
    (1, 256, 8, 8, 64, True, 0, 0.0, jnp.bfloat16),
    (1, 160, 4, 1, 256, True, 0, 0.0, jnp.float32),   # MQA, gemma head_dim
]


@pytest.mark.parametrize("b,s,h,kv,hd,causal,win,cap,dtype", ATTN_CASES)
def test_flash_attention_matches_dense(b, s, h, kv, hd, causal, win, cap,
                                       dtype):
    k1, k2, k3 = jax.random.split(RNG, 3)
    q = jax.random.normal(k1, (b, s, h, hd), dtype)
    k = jax.random.normal(k2, (b, s, kv, hd), dtype)
    v = jax.random.normal(k3, (b, s, kv, hd), dtype)
    out = attn_ops.flash_attention(q, k, v, scale=hd ** -0.5, causal=causal,
                                   window=win, attn_softcap=cap)
    ref = dense_reference(q, k, v, scale=hd ** -0.5, causal=causal,
                          window=win, attn_softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_grads_flow():
    k1, k2, k3 = jax.random.split(RNG, 3)
    q = jax.random.normal(k1, (1, 128, 4, 32))
    k = jax.random.normal(k2, (1, 128, 2, 32))
    v = jax.random.normal(k3, (1, 128, 2, 32))

    def loss_kernel(q):
        return attn_ops.flash_attention(q, k, v, scale=0.2).sum()

    def loss_ref(q):
        return dense_reference(q, k, v, scale=0.2).sum()

    g1 = jax.grad(loss_kernel)(q)
    g2 = jax.grad(loss_ref)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (B, L, H, P, N, chunk)
    (2, 128, 4, 32, 16, 32),
    (1, 256, 8, 64, 128, 64),
    (2, 96, 2, 32, 64, 32),
    (1, 64, 4, 128, 128, 64),
]


@pytest.mark.parametrize("b,l,h,p,n,q", SSD_CASES)
def test_ssd_kernel_matches_ref(b, l, h, p, n, q):
    ks = jax.random.split(RNG, 5)
    x = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, l, n)) * 0.3
    cm = jax.random.normal(ks[4], (b, l, n)) * 0.3
    y1, s1 = ssd_ops.ssd_chunked(x, dt, a, bm, cm, q)
    y2, s2 = ssd_chunked_ref(x, dt, a, bm, cm, q)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_ssd_decode_consistent_with_chunked():
    """Sequential decode steps == chunked scan over the same tokens."""
    from repro.models.ssm import ssd_decode_step
    b, l, h, p, n = 1, 16, 2, 8, 4
    ks = jax.random.split(RNG, 5)
    x = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, l, n)) * 0.3
    cm = jax.random.normal(ks[4], (b, l, n)) * 0.3
    y_ref, s_ref = ssd_chunked_ref(x, dt, a, bm, cm, chunk=8)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        y, state = ssd_decode_step(state, x[:, t], dt[:, t], a,
                                   bm[:, t], cm[:, t])
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)
