"""Loss / optimizer / schedules / compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.train.compress import dequantize, quantize
from repro.train.loss import lm_loss
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   clip_by_global_norm, init_opt_state,
                                   schedule_lr)

RNG = jax.random.PRNGKey(0)


class TestLoss:
    def test_matches_manual_ce(self):
        cfg = get_config("tinyllama-1.1b").reduced()
        logits = jax.random.normal(RNG, (2, 8, cfg.padded_vocab))
        labels = jax.random.randint(RNG, (2, 8), 0, cfg.vocab_size)
        loss, metrics = lm_loss(cfg, logits, labels)
        # manual on real vocab slice
        l = np.asarray(logits)[..., :cfg.vocab_size]
        lse = np.log(np.sum(np.exp(l - l.max(-1, keepdims=True)), -1)) \
            + l.max(-1)
        gold = np.take_along_axis(l, np.asarray(labels)[..., None],
                                  -1)[..., 0]
        np.testing.assert_allclose(float(loss), float((lse - gold).mean()),
                                   rtol=1e-5)

    def test_padded_vocab_excluded(self):
        cfg = get_config("minicpm-2b").reduced()   # vocab 512, padded 2048
        logits = jnp.zeros((1, 4, cfg.padded_vocab))
        # give huge logit to a PADDING column: must not affect loss
        logits = logits.at[..., cfg.vocab_size + 5].set(100.0)
        labels = jnp.zeros((1, 4), jnp.int32)
        loss, _ = lm_loss(cfg, logits, labels)
        np.testing.assert_allclose(float(loss), np.log(cfg.vocab_size),
                                   rtol=1e-4)

    def test_mask(self):
        cfg = get_config("tinyllama-1.1b").reduced()
        logits = jax.random.normal(RNG, (1, 6, cfg.padded_vocab))
        labels = jax.random.randint(RNG, (1, 6), 0, cfg.vocab_size)
        mask = jnp.asarray([[1, 1, 1, 0, 0, 0]], jnp.float32)
        full, _ = lm_loss(cfg, logits, labels)
        masked, m = lm_loss(cfg, logits, labels, mask)
        assert m["tokens"] == 3.0
        assert abs(float(masked) - float(full)) > 1e-6


class TestSchedules:
    def test_warmup_and_cosine(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              schedule="cosine", min_lr_frac=0.1)
        assert float(schedule_lr(cfg, jnp.int32(0))) == 0.0
        assert abs(float(schedule_lr(cfg, jnp.int32(10))) - 1.0) < 1e-6
        assert abs(float(schedule_lr(cfg, jnp.int32(100))) - 0.1) < 1e-5

    def test_wsd_stable_then_decay(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              schedule="wsd", wsd_decay_frac=0.2,
                              min_lr_frac=0.0)
        # stable plateau
        assert abs(float(schedule_lr(cfg, jnp.int32(50))) - 1.0) < 1e-6
        assert abs(float(schedule_lr(cfg, jnp.int32(82))) - 1.0) < 2e-1
        # decays at the end
        assert float(schedule_lr(cfg, jnp.int32(100))) < 0.05

    def test_minicpm_selects_wsd(self):
        from repro.train.optimizer import optimizer_for_arch
        assert optimizer_for_arch("minicpm-2b").schedule == "wsd"
        assert optimizer_for_arch("tinyllama-1.1b").schedule == "cosine"


class TestAdamW:
    def test_descends_quadratic(self):
        params = {"w": jnp.asarray([3.0, -2.0])}
        cfg = OptimizerConfig(lr=0.1, warmup_steps=0, weight_decay=0.0,
                              schedule="const")
        state = init_opt_state(params)
        for _ in range(50):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(cfg, params, grads, state)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.5

    def test_clip(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
        assert float(norm) > 30.0

    def test_bf16_moments(self):
        params = {"w": jnp.ones((4,))}
        cfg = OptimizerConfig(moment_dtype="bfloat16", warmup_steps=0)
        state = init_opt_state(params, "bfloat16")
        assert state["m"]["w"].dtype == jnp.bfloat16
        params2, state, _ = adamw_update(cfg, params,
                                         {"w": jnp.ones((4,))}, state)
        assert state["m"]["w"].dtype == jnp.bfloat16
        assert bool(jnp.all(jnp.isfinite(params2["w"])))


class TestCompression:
    def test_quantize_unbiased(self):
        x = jax.random.normal(RNG, (2000,))
        errs = []
        for i in range(20):
            q, s = quantize(x, jax.random.PRNGKey(i))
            errs.append(np.asarray(dequantize(q, s) - x))
        mean_err = np.mean(errs, axis=0)
        # stochastic rounding: bias -> 0 as we average draws
        assert np.abs(mean_err).mean() < np.abs(errs[0]).mean() / 2

    def test_quantize_bounded_error(self):
        x = jax.random.normal(RNG, (1000,)) * 5
        q, s = quantize(x, RNG)
        err = np.abs(np.asarray(dequantize(q, s) - x))
        assert err.max() <= float(s) + 1e-6      # one quantization step

    def test_int8_wire_format(self):
        x = jax.random.normal(RNG, (64,))
        q, _ = quantize(x, RNG)
        assert q.dtype == jnp.int8
