"""Hierarchical meta-GA + scaling policy + elastic integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GAConfig
from repro.core.engine import GAEngine
from repro.core.meta import (META_GENE_SPEC, decode_meta_genome,
                             make_inner_ga, make_meta_fitness,
                             meta_ga_config)
from repro.core.scaling import (PRESET_HORIZONTAL, PRESET_VERTICAL,
                                ScalingPlan, plan_scaling)
from repro.fitness import sphere


class TestMetaGA:
    def test_inner_ga_improves_over_random(self):
        cfg = GAConfig(num_genes=4, lower=-2.0, upper=2.0,
                       fused_operators=False)
        inner = make_inner_ga(cfg, sphere, p_max=16, generations=10)
        hg = jnp.asarray([12.0, 0.9, 0.5, 20.0, 15.0])
        best = inner(hg, jax.random.PRNGKey(0))
        assert float(best) < 1.0                 # random-init ~ several

    def test_variable_pop_size_masked(self):
        cfg = GAConfig(num_genes=3, lower=-1.0, upper=1.0,
                       fused_operators=False)
        inner = make_inner_ga(cfg, sphere, p_max=32, generations=3)
        # tiny pop (2) and full pop (32) both run at static shapes
        for p in (2.0, 32.0):
            hg = jnp.asarray([p, 0.9, 0.5, 20.0, 15.0])
            out = inner(hg, jax.random.PRNGKey(1))
            assert bool(jnp.isfinite(out))

    def test_meta_fitness_shape_and_seed_reduction(self):
        cfg = GAConfig(num_genes=3, lower=-1.0, upper=1.0,
                       fused_operators=False)
        mf = make_meta_fitness(cfg, sphere, p_max=8, generations=3,
                               num_seeds=2)
        h = jnp.asarray([[8.0, 0.9, 0.5, 20.0, 15.0],
                         [4.0, 0.1, 0.1, 5.0, 5.0]])
        out = jax.jit(mf)(h)
        assert out.shape == (2, 1)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_meta_config_bounds_match_table4(self):
        cfg = meta_ga_config()
        lo, hi = cfg.bounds()
        assert list(lo) == [s[1] for s in META_GENE_SPEC]
        assert list(hi) == [s[2] for s in META_GENE_SPEC]

    def test_decode(self):
        d = decode_meta_genome(jnp.asarray([100.0, 0.5, 0.25, 10.0, 90.0]))
        assert float(d["pop_size"]) == 100.0
        assert float(d["eta_cx"]) == 90.0


class TestScalingPolicy:
    def test_presets_match_paper_table3(self):
        assert PRESET_HORIZONTAL.chips == 3072 == PRESET_VERTICAL.chips
        assert PRESET_HORIZONTAL.horizontal == 384
        assert PRESET_VERTICAL.vertical == 128

    def test_auto_plan_respects_sim_parallelism(self):
        plan = plan_scaling(256, pop_total=512, sim_parallelism=1)
        assert plan.vertical == 1 and plan.horizontal == 256
        plan = plan_scaling(256, pop_total=512, sim_parallelism=2004)
        assert plan.vertical > 1
        assert plan.horizontal * plan.vertical <= 256 * 2

    def test_prefer_modes(self):
        assert plan_scaling(64, pop_total=10, prefer="horizontal") \
            == ScalingPlan(64, 1)
        v = plan_scaling(64, pop_total=10, sim_parallelism=100,
                         prefer="vertical")
        assert v.vertical == 64
