"""Unified DispatchBackend conformance suite.

Every decoupled backend — HostPool, the batch-scheduled spool behind the
SLURM and Kubernetes mock schedulers, and the persistent-worker message
queue over BOTH its transports (file broker and socket broker) — must
behave identically behind the ``DispatchBackend`` protocol: eager and
jitted evaluation matching inline fitness, composition with the
broker's padded cost-balanced dispatch, pickled-fitness delivery,
drain-before-close, and timeout -> re-queue -> retry-succeeds. This
module holds that contract ONCE, parametrized over all five backends;
``test_batchq.py`` and ``test_mq.py`` import :func:`run_conformance` /
:func:`make_backend` for their backend-specific variants.

Collected by tier-1 via ``pyproject.toml``'s ``python_files`` and named
explicitly (first) by the ``scripts/ci.sh`` fast lane, so a contract
regression fails before the backend-specific suites even start.
"""
import functools
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.broker import Broker, DispatchBackend, HostPoolBackend
from repro.fitness import sphere
from repro.fitness import hostsim
from repro.runtime.batchq import (KubernetesScheduler, LocalMockScheduler,
                                  MockKubectl, SlurmArrayBackend)
from repro.runtime.mq import LocalWorkerPool, QueueBackend
from repro.runtime.netbroker import NetWorkerPool, SocketQueueBackend

SPEC = "repro.fitness.hostsim:sphere"

#: the five decoupled execution substrates behind the ONE protocol —
#: "mq-net" is the socket transport of the same queue contract, so the
#: file and socket brokers pass the IDENTICAL contract suite
BACKEND_KINDS = ("hostpool", "slurm-mock", "k8s-mock", "mq", "mq-net")


def run_conformance(backend, n=29):
    """The shared acceptance block: eager + jitted evaluation match the
    inline fitness, and the backend composes with the broker's padded
    cost-balanced dispatch under jit (N % W != 0 exercises the sentinel
    pads)."""
    genomes = jax.random.uniform(jax.random.PRNGKey(0), (n, 5))
    direct = np.asarray(sphere(genomes))
    assert isinstance(backend, DispatchBackend)
    # eager and jitted evaluation match inline fitness
    np.testing.assert_allclose(np.asarray(backend(genomes)), direct,
                               rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(jax.jit(backend.__call__)(genomes)), direct, rtol=1e-6)
    # composes with the broker's padded balanced dispatch under jit
    broker = Broker(cost_fn=lambda g: jnp.sum(jnp.abs(g), -1) + 0.1,
                    num_workers=4, backend=backend)
    fit, stats = jax.jit(broker.evaluate)(genomes)
    np.testing.assert_allclose(np.asarray(fit), direct, rtol=1e-6)
    assert float(stats["balanced"]) == 1.0
    assert int(stats["padded"]) == (-(-n // 4) * 4) - n


def make_backend(kind, tmp_path, *, fitness_fn=None, fn_spec=None,
                 pool_fn=None, hang_substrings=(), chunk_timeout_s=60,
                 max_retries=2, num_workers=3):
    """One decoupled backend per ``kind``, same knobs everywhere.

    ``fitness_fn``/``fn_spec`` select the payload path (pickle vs import
    spec; defaults to the numpy sphere spec). ``pool_fn`` overrides
    resolution inside the mq thread pool for unpicklable closures.
    ``hang_substrings`` injects lost nodes/pods into the mock schedulers
    (ignored by hostpool/mq — inject through the fitness there)."""
    if fitness_fn is None and fn_spec is None:
        fn_spec = SPEC
    if kind == "hostpool":
        fn = fitness_fn if fitness_fn is not None else hostsim.sphere
        return HostPoolBackend(fn, num_workers=num_workers,
                               chunk_timeout_s=chunk_timeout_s,
                               max_retries=max_retries)
    if kind in ("slurm-mock", "k8s-mock"):
        scheduler = (
            LocalMockScheduler(mode="thread",
                               hang_substrings=hang_substrings)
            if kind == "slurm-mock" else
            KubernetesScheduler(runner=MockKubectl(
                mode="thread", hang_substrings=hang_substrings)))
        return SlurmArrayBackend(fitness_fn, fn_spec=fn_spec,
                                 num_workers=num_workers,
                                 scheduler=scheduler,
                                 spool_dir=str(tmp_path / "spool"),
                                 chunk_timeout_s=chunk_timeout_s,
                                 max_retries=max_retries,
                                 poll_interval_s=0.005)
    if kind == "mq":
        pool = LocalWorkerPool(num_workers=num_workers, mode="thread",
                               lease_s=30.0, poll_s=0.005, fn=pool_fn)
        return QueueBackend(fitness_fn, fn_spec=fn_spec,
                            num_workers=num_workers, worker_pool=pool,
                            mq_dir=str(tmp_path / "mq"),
                            chunk_timeout_s=chunk_timeout_s,
                            max_retries=max_retries,
                            poll_interval_s=0.005)
    if kind == "mq-net":
        pool = NetWorkerPool(num_workers=num_workers, mode="thread",
                             lease_s=30.0, poll_s=0.005, fn=pool_fn)
        return SocketQueueBackend(fitness_fn, fn_spec=fn_spec,
                                  num_workers=num_workers,
                                  worker_pool=pool,
                                  chunk_timeout_s=chunk_timeout_s,
                                  max_retries=max_retries,
                                  poll_interval_s=0.005)
    raise ValueError(kind)


@pytest.mark.parametrize("kind", BACKEND_KINDS)
class TestBackendContract:
    def test_conformance_and_padded_broker_compose(self, kind, tmp_path):
        with make_backend(kind, tmp_path) as backend:
            run_conformance(backend)

    def test_pickled_fitness(self, kind, tmp_path):
        """No import spec: workers load the callable from the pickle
        payload (hostpool calls it directly — same contract surface)."""
        with make_backend(kind, tmp_path,
                          fitness_fn=hostsim.rastrigin) as backend:
            g = np.random.default_rng(1).uniform(-1, 1, (11, 4)).astype(
                np.float32)
            np.testing.assert_allclose(backend._host_eval(g),
                                       hostsim.rastrigin(g), rtol=1e-5)

    def test_drain_before_close(self, kind, tmp_path):
        """close() while an evaluation is in flight must drain it — the
        pipelined epoch loop can still have a pure_callback polling when
        the caller tears the backend down — and later use must raise."""
        slow = functools.partial(hostsim.delay_sphere, base_s=0.03)
        g = np.random.default_rng(7).uniform(-1, 1, (12, 3)).astype(
            np.float32)
        g[:, 0] = -1.0                           # no hot rows: base_s only
        with make_backend(kind, tmp_path, fitness_fn=slow,
                          pool_fn=slow) as backend:
            box = {}
            t = threading.Thread(
                target=lambda: box.update(out=backend._host_eval(g)),
                daemon=True)
            t.start()
            time.sleep(0.05)                     # eval is in flight
            backend.close()                      # must drain, not strand
            t.join(timeout=30)
            assert not t.is_alive()
            np.testing.assert_allclose(box["out"], hostsim.sphere(g),
                                       rtol=1e-6)
            with pytest.raises(RuntimeError, match="after close"):
                backend._host_eval(g)

    def test_timeout_then_retry_succeeds(self, kind, tmp_path):
        """The acceptance case everywhere: one chunk straggles past the
        per-chunk timeout, the re-queued attempt delivers. Mock
        schedulers lose the node/pod (accepted, never started); hostpool
        and mq get a stuck-but-alive worker via a hang-once fitness (the
        mq worker keeps heartbeating, so this is a TIMEOUT, not a
        lease re-queue)."""
        release = threading.Event()
        state = {"hung": False}
        lock = threading.Lock()

        def hang_once(genomes):
            g = np.asarray(genomes, np.float32)
            hot = bool(np.any(g[:, 0] > 0))
            with lock:
                first = hot and not state["hung"]
                if first:
                    state["hung"] = True
            if first:
                release.wait(timeout=30)
            return hostsim.sphere(g)

        g = np.random.default_rng(4).uniform(-1, 1, (24, 3)).astype(
            np.float32)
        g[:, 0] = -1.0
        if kind in ("slurm-mock", "k8s-mock"):
            kw = dict(hang_substrings=("chunk_0001_try0",))
        else:
            g[0, 0] = 1.0                        # chunk 0 carries the hot row
            kw = dict(fitness_fn=hang_once, pool_fn=hang_once)
        with make_backend(kind, tmp_path, chunk_timeout_s=0.5,
                          **kw) as backend:
            try:
                out = backend._host_eval(g)
                np.testing.assert_allclose(out, hostsim.sphere(g),
                                           rtol=1e-6)
                # a loaded CI box may time out healthy chunks too: >= not ==
                assert backend.stats["retries"] >= 1
                if "timeouts" in backend.stats:
                    assert backend.stats["timeouts"] >= 1
            finally:
                release.set()                    # free the hung worker so
                                                 # close() doesn't wait on it
