"""The protocol model checker itself (repro.analysis.proto).

A checker is only trusted if it can FAIL: alongside the fsmodel
semantics (atomic replace, torn-tmp visibility, crash droppings) and
the good-spec pass, every seeded-bad protocol variant must produce a
counterexample — each one models a real implementation mistake the
queue contract forbids (claim via copy-then-delete, release before
publish, re-queue without a delivery bump, re-queue burning the retry
budget, non-atomic publish, no post-close tombstone).
"""
import os
import subprocess
import sys

import pytest

from repro.analysis.proto import fsmodel as F
from repro.analysis.proto.explorer import explore
from repro.analysis.proto.spec import SpecConfig

REPO_SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


# ---------------------------------------------------------------------------
# fsmodel: the abstract shared filesystem
# ---------------------------------------------------------------------------

class TestFsModel:
    def test_publish_is_atomic_no_tmp_ever_visible(self):
        fs = F.Fs()
        fs.publish("results/a.npz", ("res", 0))
        assert fs.listdir("results") == ["a.npz"]
        assert fs.read("results/a.npz") == ("res", 0)

    def test_torn_write_leaves_only_the_tmp_dropping(self):
        # crash mid-atomic-write: the final name NEVER appears, the tmp
        # sibling DOES — pollers must see (and skip) it
        fs = F.Fs()
        fs.torn("results/a.npz")
        assert not fs.exists("results/a.npz")
        assert fs.listdir("results") == ["a.npz" + F.TMP_SUFFIX]
        assert fs.read("results/a.npz" + F.TMP_SUFFIX) is F.TORN

    def test_rename_moves_content_and_raises_when_lost(self):
        fs = F.Fs()
        fs.write_raw("tasks/t.npz", ("task",))
        fs.rename("tasks/t.npz", "claimed/t.npz")
        assert not fs.exists("tasks/t.npz")
        assert fs.read("claimed/t.npz") == ("task",)
        # the losing side of a claim race: source already gone
        with pytest.raises(F.FsError):
            fs.rename("tasks/t.npz", "claimed/t.npz")

    def test_utime_freshens_and_raises_on_missing(self):
        fs = F.Fs()
        fs.write_raw("claimed/t.npz.lease", F.STALE)
        fs.utime("claimed/t.npz.lease")
        assert fs.read("claimed/t.npz.lease") == F.FRESH
        fs.remove("claimed/t.npz.lease")
        with pytest.raises(F.FsError):
            fs.utime("claimed/t.npz.lease")

    def test_freeze_excludes_the_clock(self):
        # converging interleavings must merge even when they took
        # different numbers of steps to converge
        a, b = F.Fs(), F.Fs()
        a.write_raw("x", 1)
        b.write_raw("x", 1)
        b.clock += 7
        assert a.freeze() == b.freeze()
        b.write_raw("y", 1)
        assert a.freeze() != b.freeze()

    def test_clone_is_independent(self):
        fs = F.Fs()
        fs.write_raw("x", 1)
        fork = fs.clone()
        fork.remove("x")
        assert fs.exists("x") and not fork.exists("x")

    def test_task_name_round_trip_shapes(self):
        name = F.task_file("a", 0, 1, 0, 2)
        assert name == "ra_j000000_c0001_t0_d2.npz"
        assert F.result_file(name).endswith(".result.npz")
        assert F.fail_file(name).endswith(".fail")
        assert F.lease_file(name) == name + ".lease"


# ---------------------------------------------------------------------------
# explorer: seeded-bad protocols MUST produce counterexamples
# ---------------------------------------------------------------------------

BAD_VARIANTS = [
    # (variant, cfg overrides, substring expected in the violation,
    #  max acceptable counterexample length — BFS minimality guard)
    ("copy_claim", {}, "claim not exclusive", 4),
    ("release_before_publish", {}, "deadlock", 16),
    ("requeue_no_bump", {}, "delivery", 8),
    ("requeue_burns_retry", {}, "retry", 8),
    ("torn_publish", {}, "malformed", 10),
    ("no_tombstone", {"chunks": 1, "max_crashes": 0}, "leak", 24),
]


@pytest.mark.parametrize("variant,over,needle,max_len",
                         BAD_VARIANTS, ids=[v[0] for v in BAD_VARIANTS])
def test_seeded_bad_variant_produces_counterexample(
        variant, over, needle, max_len):
    cfg = SpecConfig(variant=variant, **over)
    result = explore(cfg, max_depth=60, max_states=300_000)
    assert not result.ok, f"{variant}: the checker failed to fail"
    assert needle in result.violation, result.violation
    assert 0 < len(result.schedule) <= max_len, \
        f"BFS counterexample not minimal: {result.schedule}"
    assert result.stop_reason == "violation"


def test_good_spec_single_chunk_sweeps_clean_and_complete():
    result = explore(SpecConfig(chunks=1), max_depth=80)
    assert result.ok and result.complete, result.violation
    assert result.states > 1_000        # crash injection actually explored
    assert result.stop_reason == "exhausted"


def test_rpc_broker_variant_sweeps_clean_and_complete():
    """The socket transport's crash-mid-publish story (torn FRAME
    discarded whole by the server, nothing lands) satisfies the same
    contract — the transport swap is safe by the model, not by hope."""
    result = explore(SpecConfig(chunks=1, variant="rpc_broker"),
                     max_depth=80)
    assert result.ok and result.complete, result.violation
    assert result.states > 1_000
    assert result.stop_reason == "exhausted"


def test_bounded_sweep_reports_incomplete_not_clean():
    # "no violation found" under a bound must never read as a full pass
    result = explore(SpecConfig(), max_depth=80, max_states=50)
    assert result.ok and not result.complete
    assert result.stop_reason == "max_states"


@pytest.mark.slow
def test_good_spec_full_ci_bound_sweep():
    """The verify-protocol CI lane's sweep: 2 workers x 2 chunks with a
    delivery bump and a crash injection, to quiescence, complete."""
    result = explore(SpecConfig(), max_depth=80, max_states=500_000)
    assert result.ok and result.complete, result.violation
    assert result.states > 100_000


# ---------------------------------------------------------------------------
# CLI exit codes
# ---------------------------------------------------------------------------

def _run_protocol_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(REPO_SRC) + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--protocol", *args],
        capture_output=True, text=True, env=env)


class TestProtocolCli:
    def test_violation_exits_1_with_minimal_schedule(self):
        proc = _run_protocol_cli("--variant", "copy_claim")
        assert proc.returncode == 1
        assert "VIOLATION" in proc.stdout
        assert "minimal counterexample" in proc.stdout
        assert "w0.claim_copy" in proc.stdout

    def test_clean_complete_exits_0_and_prints_states(self):
        proc = _run_protocol_cli("--tasks", "1")
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "states=" in proc.stdout
        assert "OK: all invariants hold" in proc.stdout

    def test_bounded_sweep_exits_3(self):
        proc = _run_protocol_cli("--max-states", "50")
        assert proc.returncode == 3
        assert "complete=False" in proc.stdout

    def test_json_output_parses(self):
        import json
        proc = _run_protocol_cli("--tasks", "1", "--json")
        out = json.loads(proc.stdout)
        assert out["ok"] and out["complete"]
        assert out["states"] > 1_000
