"""Socket broker frame protocol: properties and chaos.

Three layers, matching the netbroker docstring's failure-semantics
claims exactly:

* frame codec properties — length-prefixed encode/recv round-trips for
  arbitrary header shapes and payload sizes (property-style sweep via
  the hypothesis stub), and the codec's protocol bounds;
* torn/partial-frame chaos against a REAL server — a connection
  dropped mid-prefix, mid-header, or mid-blob (including mid-RESULT,
  the money case) must never corrupt queue state: the half-sent op
  simply never happened, the claim stays recoverable via lease expiry,
  and the task is never lost;
* reconnect semantics — a worker whose connection dies mid-task
  resumes claiming on a fresh connection without double-claiming its
  own lost task or racing another claimant for the re-queued delivery
  (claim exclusivity across reconnects).
"""
import io
import socket
import struct

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.fitness import hostsim
from repro.runtime.mq import task_name
from repro.runtime.netbroker import (MAX_BLOB, MAX_HEADER, BrokerClient,
                                     BrokerError, BrokerServer,
                                     encode_frame, recv_frame)

SPEC = "repro.fitness.hostsim:sphere"


# ---------------------------------------------------------------------------
# Frame codec properties
# ---------------------------------------------------------------------------

def _round_trip(header, blob):
    """Push one encoded frame through a real socket pair and decode."""
    a, b = socket.socketpair()
    try:
        a.sendall(encode_frame(header, blob))
        return recv_frame(b)
    finally:
        a.close()
        b.close()


@settings(max_examples=25, deadline=None)
@given(blob_size=st.integers(min_value=0, max_value=1 << 17),
       n_keys=st.integers(min_value=0, max_value=8),
       seed=st.integers(min_value=0, max_value=2**31))
def test_frame_round_trip_arbitrary_sizes(blob_size, n_keys, seed):
    rng = np.random.default_rng(seed)
    header = {"op": "X"}
    for i in range(n_keys):
        # JSON-representable soup: strings, ints, floats, None, lists
        header[f"k{i}"] = [int(rng.integers(-1e9, 1e9)),
                          float(rng.uniform(-1e6, 1e6)), None,
                          "x" * int(rng.integers(0, 64))]
    blob = rng.integers(0, 256, size=blob_size, dtype=np.uint8).tobytes()
    got_header, got_blob = _round_trip(header, blob)
    assert got_header == header
    assert got_blob == blob


def test_frame_boundary_sizes_round_trip():
    # the sizes that break off-by-one length-prefix handling
    for size in (0, 1, 2, 7, 8, 9, (1 << 16) - 1, 1 << 16, (1 << 16) + 1):
        blob = bytes(size)
        header, got = _round_trip({"op": "B", "size": size}, blob)
        assert header["size"] == size and got == blob


def test_frame_protocol_bounds_rejected_at_encode():
    with pytest.raises(ValueError):
        encode_frame({"op": "X", "pad": "y" * (MAX_HEADER + 1)})


def test_recv_frame_rejects_corrupt_prefix():
    # a garbage prefix claiming a multi-GB blob must fail fast, not
    # allocate — ConnectionError, the drop-the-connection signal
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack("!II", MAX_HEADER + 1, MAX_BLOB))
        with pytest.raises(ConnectionError):
            recv_frame(b)
    finally:
        a.close()
        b.close()


def test_recv_frame_short_read_is_connection_error():
    a, b = socket.socketpair()
    try:
        frame = encode_frame({"op": "X"}, b"payload")
        a.sendall(frame[: len(frame) - 3])       # torn mid-blob
        a.close()
        with pytest.raises(ConnectionError):
            recv_frame(b)
    finally:
        b.close()


# ---------------------------------------------------------------------------
# Torn/partial frames against a real server: queue state never corrupts
# ---------------------------------------------------------------------------

@pytest.fixture
def server():
    with BrokerServer() as s:
        yield s


@pytest.fixture
def mgr(server):
    client = BrokerClient(server.addr)
    client.register_run("a", fn_spec=SPEC)
    yield client
    client.close()


def _enqueue_one(mgr, chunk=0, delivery=0):
    name = task_name("a", 0, chunk, 0, delivery)
    g = np.random.default_rng(chunk).uniform(-1, 1, (4, 3)).astype(
        np.float32)
    mgr.enqueue(name, g)
    return name, g


def _raw_conn(server):
    s = socket.create_connection(server.addr, timeout=10.0)
    s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return s


@pytest.mark.parametrize("cut", ["mid_prefix", "mid_header", "mid_blob",
                                 "garbage_prefix"])
def test_torn_request_frame_never_touches_queue_state(server, mgr, cut):
    """A connection dropped partway through ANY request frame: the
    server discards the partial frame whole — the enqueued task is
    still there, still claimable, and a fresh client works."""
    name, _ = _enqueue_one(mgr)
    raw = _raw_conn(server)
    frame = encode_frame({"op": "CLAIM", "bad_runs": {}, "poll_s": None})
    if cut == "mid_prefix":
        raw.sendall(frame[:3])
    elif cut == "mid_header":
        raw.sendall(frame[:12])
    elif cut == "mid_blob":
        blob_frame = encode_frame({"op": "ENQUEUE",
                                   "name": task_name("a", 0, 9, 0, 0)},
                                  b"x" * 1024)
        raw.sendall(blob_frame[: len(blob_frame) - 100])
    else:
        raw.sendall(struct.pack("!II", 0xFFFFFFFF, 0xFFFFFFFF))
    raw.close()
    # the queue is untouched: exactly the one enqueued task, claimable
    listing = mgr.listdir()
    assert listing["tasks"] == [name]
    assert listing["claimed"] == []
    reply, _ = mgr.claim()
    assert reply["name"] == name
    mgr.release(name)


def test_connection_drop_mid_result_frame_is_not_a_lost_task(server, mgr):
    """THE at-least-once money case: a worker dies mid-RESULT frame.
    Nothing lands (no result, no torn dropping), the claim + lease
    survive, and the normal stale-lease re-queue recovers the task —
    released-or-expired, never lost."""
    name, g = _enqueue_one(mgr)
    w = BrokerClient(server.addr)
    reply, blob = w.claim()
    assert reply["name"] == name
    w.lease(name)
    fit = np.asarray(hostsim.sphere(np.load(io.BytesIO(blob))["genomes"]),
                     np.float32)
    # craft the worker's RESULT frame, send HALF of it, drop the socket
    frame = encode_frame({"op": "RESULT", "name": name, "duration": 0.01,
                          "busy": 0.01, "shape": list(fit.shape)},
                         fit.tobytes())
    w._sock.sendall(frame[: len(frame) // 2])
    w._sock.close()
    # nothing landed: no result, no fail, no torn dropping
    assert mgr.result_fetch(name) is None
    assert mgr.fail_fetch(name) is None
    listing = mgr.listdir()
    assert not [x for x in listing["results"] if x.startswith("ra_")]
    # the claim + lease survived — the manager's recovery path works:
    # the lease goes stale, the chunk is re-queued under a bumped
    # delivery, and a live worker answers it
    claimed, age = mgr.lease_state(name)
    assert claimed
    mgr.backdate_lease(name, 9999.0)
    claimed, age = mgr.lease_state(name)
    assert claimed and age > 9000
    bumped = task_name("a", 0, 0, 0, 1)
    assert mgr.requeue(name, bumped)
    w2 = BrokerClient(server.addr)
    reply2, blob2 = w2.claim()
    assert reply2["name"] == bumped
    w2.lease(bumped)
    fit2 = np.asarray(
        hostsim.sphere(np.load(io.BytesIO(blob2))["genomes"]),
        np.float32).reshape(4, -1)
    w2.result(bumped, fit2, 0.01)
    w2.release(bumped)
    w2.close()
    got = mgr.result_fetch(bumped)
    assert got is not None
    np.testing.assert_allclose(got[0], hostsim.sphere(g), rtol=1e-6)


def test_reconnecting_worker_resumes_without_duplicate_claim(server, mgr):
    """A worker's connection dies mid-task; it reconnects and resumes
    claiming. Exclusivity across the reconnect: it cannot re-claim its
    own lost task (still leased in claimed/), and once the manager
    re-queues, exactly ONE claimant wins the bumped delivery."""
    name, g = _enqueue_one(mgr)
    w = BrokerClient(server.addr)
    reply, _ = w.claim()
    assert reply["name"] == name
    w.lease(name)
    w._sock.close()                              # the cut, mid-task
    w.connect()                                  # the worker's recovery
    reply2, _ = w.claim()
    assert reply2["name"] is None, \
        "reconnected worker stole its own still-leased claim"
    # manager-side recovery: stale lease -> delivery bump
    mgr.backdate_lease(name, 9999.0)
    bumped = task_name("a", 0, 0, 0, 1)
    assert mgr.requeue(name, bumped)
    # two live claimants race the re-queued task: one winner, exactly
    reply_a, blob_a = w.claim()
    w3 = BrokerClient(server.addr)
    reply_b, _ = w3.claim()
    winners = [r["name"] for r in (reply_a, reply_b)
               if r["name"] is not None]
    assert winners == [bumped], winners
    fit = np.asarray(
        hostsim.sphere(np.load(io.BytesIO(blob_a))["genomes"]),
        np.float32).reshape(4, -1)
    w.lease(bumped)
    w.result(bumped, fit, 0.01)
    w.release(bumped)
    w.close()
    w3.close()
    got = mgr.result_fetch(bumped)
    assert got is not None
    np.testing.assert_allclose(got[0], hostsim.sphere(g), rtol=1e-6)
    listing = mgr.listdir()
    assert listing["claimed"] == []


def test_server_error_reply_carries_traceback(server):
    client = BrokerClient(server.addr)
    try:
        with pytest.raises(BrokerError, match="unknown op"):
            client.call("NO_SUCH_OP")
        # the connection survives an error reply — protocol errors are
        # replies, not disconnects
        client.ping()
    finally:
        client.close()
