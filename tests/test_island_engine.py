"""Island model + engine integration tests (paper §3/§4 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GAConfig
from repro.core import island
from repro.core.broker import Broker
from repro.core.engine import GAEngine
from repro.core.population import init_population, best_of
from repro.fitness import rastrigin, sphere


def _cfg(**kw):
    base = dict(num_genes=6, pop_per_island=16, num_islands=4,
                generations_per_epoch=3, num_epochs=5,
                lower=-5.12, upper=5.12, mutation_prob=0.7,
                mutation_eta=20.0, crossover_prob=0.9, crossover_eta=15.0,
                fused_operators=False, seed=11)
    base.update(kw)
    return GAConfig(**base)


class TestGeneration:
    def test_elitism_best_never_worsens(self):
        cfg = _cfg()
        broker = Broker(sphere)
        gen = jax.jit(island.make_generation_step(cfg, broker))
        pop = init_population(cfg, jax.random.PRNGKey(0))
        pop = island.evaluate_population(cfg, broker, pop)
        best = float(jnp.min(pop.fitness))
        for _ in range(5):
            pop, _ = gen(pop, None)
            new_best = float(jnp.min(pop.fitness))
            assert new_best <= best + 1e-6
            best = new_best

    def test_generation_counter_and_evals(self):
        cfg = _cfg()
        broker = Broker(sphere)
        gen = island.make_generation_step(cfg, broker)
        pop = init_population(cfg, jax.random.PRNGKey(0))
        pop = island.evaluate_population(cfg, broker, pop)
        evals0 = float(pop.evals)
        pop, _ = gen(pop, None)
        assert int(pop.generation) == 1
        assert float(pop.evals) == evals0 + cfg.global_pop


class TestMigration:
    def test_ring_sends_best_to_next_island(self):
        cfg = _cfg(num_migrants=1)
        pop = init_population(cfg, jax.random.PRNGKey(0))
        # craft fitness: island i's best value = i
        fit = jnp.tile(jnp.arange(cfg.num_islands, dtype=jnp.float32)
                       [:, None, None], (1, cfg.pop_per_island, 1)) + 1.0
        fit = fit.at[:, 0, 0].set(jnp.arange(cfg.num_islands,
                                             dtype=jnp.float32))
        pop = pop._replace(fitness=fit)
        newpop = island.migrate_ring(cfg, pop)
        # island k+1 must now contain fitness value k (migrated best)
        for k in range(cfg.num_islands):
            dst = (k + 1) % cfg.num_islands
            assert float(jnp.min(newpop.fitness[dst])) <= k
        assert int(newpop.epoch) == 1

    def test_migration_preserves_population_size(self):
        cfg = _cfg()
        broker = Broker(sphere)
        pop = init_population(cfg, jax.random.PRNGKey(0))
        pop = island.evaluate_population(cfg, broker, pop)
        newpop = island.migrate_ring(cfg, pop)
        assert newpop.genomes.shape == pop.genomes.shape


class TestEngine:
    def test_sphere_convergence(self):
        eng = GAEngine(_cfg(num_epochs=25, pop_per_island=32), sphere)
        pop, hist = eng.run()
        _, f = eng.best(pop)
        assert f[0] < 0.05
        # history monotone non-increasing best
        bests = [h["best"] for h in hist]
        assert all(b2 <= b1 + 1e-6 for b1, b2 in zip(bests, bests[1:]))

    def test_rastrigin_progress(self):
        eng = GAEngine(_cfg(num_epochs=15, pop_per_island=32), rastrigin)
        pop, hist = eng.run()
        assert hist[-1]["best"] < hist[0]["best"]

    def test_target_termination(self):
        eng = GAEngine(_cfg(num_epochs=100), sphere)
        pop, hist = eng.run(target=1.0)
        assert len(hist) < 100

    def test_deterministic_given_seed(self):
        e1 = GAEngine(_cfg(), sphere)
        e2 = GAEngine(_cfg(), sphere)
        p1, _ = e1.run(epochs=3)
        p2, _ = e2.run(epochs=3)
        np.testing.assert_array_equal(np.asarray(p1.genomes),
                                      np.asarray(p2.genomes))

    def test_odd_pop_per_island(self):
        """Regression: odd pop_per_island crashed operators.variation
        (SBX pairing); the full engine loop must run and converge."""
        eng = GAEngine(_cfg(pop_per_island=15, num_epochs=10), sphere)
        pop, hist = eng.run()
        assert pop.genomes.shape[1] == 15
        assert np.isfinite(np.asarray(pop.fitness)).all()
        assert hist[-1]["best"] <= hist[0]["best"]


class TestPipelinedEngine:
    def test_pipelined_run_matches_sync_run(self):
        """Double-buffered epoch loop (async metric reads, donated pop
        buffers) must not change the trajectory or the recorded history."""
        sync = GAEngine(_cfg(), sphere, sync_every=1, pipeline_depth=0)
        pipe = GAEngine(_cfg(), sphere, sync_every=2, pipeline_depth=2)
        p1, h1 = sync.run(epochs=5)
        p2, h2 = pipe.run(epochs=5)
        np.testing.assert_array_equal(np.asarray(p1.genomes),
                                      np.asarray(p2.genomes))
        assert [h["epoch"] for h in h1] == [h["epoch"] for h in h2]
        assert [h["best"] for h in h1] == [h["best"] for h in h2]

    def test_pipelined_history_is_complete_and_ordered(self):
        eng = GAEngine(_cfg(), sphere, sync_every=3, pipeline_depth=1)
        _, hist = eng.run(epochs=7)
        assert [h["epoch"] for h in hist] == list(range(7))

    def test_engine_balanced_dispatch_odd_pop_even_workers(self):
        """End-to-end: pop_per_island odd vs num_workers even (the HVDC
        shape) — the broker must balance, not fall back to naive."""
        cfg = _cfg(pop_per_island=18, num_islands=3)     # N = 54
        eng = GAEngine(cfg, sphere,
                       cost_fn=lambda g: jnp.sum(jnp.abs(g), -1) + 0.1,
                       num_workers=8)                    # 54 % 8 != 0
        pop, hist = eng.run(epochs=2)
        assert all(h["balanced"] == 1.0 for h in hist)
        assert np.isfinite(np.asarray(pop.fitness)).all()


class TestAsyncStructure:
    def test_generation_body_has_no_cross_island_collectives(self):
        """The paper's async-islands claim, verified structurally: the
        jitted generation contains no collective ops on 1 device and the
        islands' evolution is independent (permutation equivariance)."""
        cfg = _cfg(num_islands=2, seed=5)
        broker = Broker(sphere)
        gen = jax.jit(island.make_generation_step(cfg, broker))
        pop = init_population(cfg, jax.random.PRNGKey(2))
        pop = island.evaluate_population(cfg, broker, pop)
        out1, _ = gen(pop, None)
        # swap islands, rerun, swap back -> identical (no cross-talk)
        swap = lambda x: jnp.flip(x, axis=0)
        pop_swapped = pop._replace(genomes=swap(pop.genomes),
                                   fitness=swap(pop.fitness),
                                   rng=swap(pop.rng))
        out2, _ = gen(pop_swapped, None)
        np.testing.assert_allclose(np.asarray(out1.genomes),
                                   np.asarray(swap(out2.genomes)), rtol=1e-6)
