"""Unit tests for dry-run helpers (no 512-device init: pure parsing) and
sharding rule tables."""
import json

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, shape_applicable
from repro.launch.dryrun import (_shallow_cfg, collective_stats,
                                 _shape_bytes)
from repro.models.sharding import ShardingCtx, _leaf_spec, param_specs


class TestHLOParsing:
    def test_shape_bytes(self):
        assert _shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
        assert _shape_bytes("f32[2,2] u8[4]") == 16 + 4
        assert _shape_bytes("(f32[8], bf16[8])") == 32 + 16
        assert _shape_bytes("token[]") == 0

    def test_collective_stats(self):
        hlo = """
  %ag = bf16[64,128]{1,0} all-gather(bf16[4,128]{1,0} %p), dims={0}
  %ar.1 = f32[256]{0} all-reduce(f32[256]{0} %x), to_apply=%sum
  %cp = f32[32]{0} collective-permute(f32[32]{0} %y), pairs={{0,1}}
  %a2a = (f32[8]{0}, f32[8]{0}) all-to-all(f32[8]{0} %a, f32[8]{0} %b)
  %ard = f32[256]{0} all-reduce-done(f32[256]{0} %ar.1)
"""
        s = collective_stats(hlo)
        assert s["bytes_all-gather"] == 64 * 128 * 2
        assert s["bytes_all-reduce"] == 256 * 4
        assert s["bytes_collective-permute"] == 32 * 4
        assert s["bytes_all-to-all"] == 64
        assert s["count_all-reduce"] == 1          # -done not double-counted
        assert s["coll_bytes"] == sum(
            v for k, v in s.items() if k.startswith("bytes_"))


class TestShallowConfig:
    def test_depth_reduced_structure_preserved(self):
        cfg = get_config("jamba-1.5-large-398b")
        d1 = _shallow_cfg(cfg, 1)
        assert d1.num_layers == cfg.scan_period
        assert d1.num_periods == 1
        assert d1.d_model == cfg.d_model
        assert d1.num_experts == cfg.num_experts
        # hybrid interleave intact within the period
        kinds = [d1.mixer_kind(i) for i in range(d1.num_layers)]
        assert kinds.count("attn") == 1

    def test_encoder_scales_with_periods(self):
        cfg = get_config("whisper-large-v3")
        d2 = _shallow_cfg(cfg, 2)
        assert d2.encoder_layers == 2
        assert d2.num_layers == 2


class TestShapeContract:
    def test_long_context_only_ssm_hybrid(self):
        runs = {a for a in ("mamba2-780m", "jamba-1.5-large-398b")
                if shape_applicable(get_config(a), SHAPES["long_500k"])[0]}
        assert runs == {"mamba2-780m", "jamba-1.5-large-398b"}
        for a in ("gemma2-2b", "tinyllama-1.1b", "whisper-large-v3",
                  "qwen2-moe-a2.7b", "llava-next-34b"):
            ok, reason = shape_applicable(get_config(a), SHAPES["long_500k"])
            assert not ok and reason

    def test_all_archs_run_other_shapes(self):
        from repro.configs import list_archs
        for a in list_archs():
            for s in ("train_4k", "prefill_32k", "decode_32k"):
                assert shape_applicable(get_config(a), SHAPES[s])[0]


class _FakeMesh:
    """Duck-typed mesh for spec-rule tests (no devices needed)."""
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


class TestShardingRules:
    def _ctx(self):
        return ShardingCtx(mesh=_FakeMesh(), dp=("data",), tp="model",
                           fsdp=("data",))

    def test_attention_weights(self):
        ctx = self._ctx()
        spec = _leaf_spec(["stack", "sub0", "attn", "q"],
                          (22, 2048, 2048), ctx)
        assert spec == P(None, ("data",), "model")

    def test_moe_ep_when_divisible(self):
        ctx = self._ctx()
        spec = _leaf_spec(["stack", "sub0", "moe", "wi"],
                          (9, 16, 8192, 24576), ctx)
        assert spec == P(None, "model", None, ("data",))

    def test_moe_tp_fallback_when_not_divisible(self):
        ctx = self._ctx()
        spec = _leaf_spec(["stack", "sub0", "moe", "wi"],
                          (24, 60, 2048, 1408), ctx)
        assert spec == P(None, None, ("data",), "model")

    def test_nondivisible_dims_replicate(self):
        ctx = self._ctx()
        # 1500-row pos table cannot shard 16 ways
        spec = _leaf_spec(["enc_pos", "table"], (1500, 1280), ctx)
        assert spec == P(None, None)

    def test_param_specs_whole_tree(self):
        from repro.models.model import Model
        cfg = get_config("qwen2-moe-a2.7b").reduced()
        m = Model(cfg)
        shapes = m.param_shapes()
        specs = param_specs(shapes, self._ctx())
        flat = jax.tree_util.tree_leaves_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat) == len(jax.tree_util.tree_leaves(shapes))
        # every spec rank-matches its leaf
        shapes_flat = jax.tree_util.tree_leaves(shapes)
        for (_, spec), leaf in zip(flat, shapes_flat):
            assert len(spec) <= len(leaf.shape)
