"""Checkpoint/restart + fault tolerance + elasticity + stragglers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import Checkpointer
from repro.configs.base import GAConfig
from repro.core.engine import GAEngine
from repro.fitness import sphere
from repro.runtime.elastic import repartition_islands
from repro.runtime.straggler import backup_dispatch_eval


def _cfg(**kw):
    base = dict(num_genes=5, pop_per_island=16, num_islands=4,
                generations_per_epoch=2, num_epochs=6, lower=-2.0,
                upper=2.0, fused_operators=False, seed=3)
    base.update(kw)
    return GAConfig(**base)


class TestCheckpointer:
    def test_roundtrip_bit_exact(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=False)
        state = {"a": np.arange(10, dtype=np.float32),
                 "nest": {"b": np.ones((3, 4), np.int32),
                          "c": np.float64(3.5)}}
        ck.save(state, step=7)
        out = ck.restore()
        np.testing.assert_array_equal(out["a"], state["a"])
        np.testing.assert_array_equal(out["nest"]["b"], state["nest"]["b"])
        assert float(out["nest"]["c"]) == 3.5

    def test_corruption_detected(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save({"a": np.arange(100, dtype=np.float32)}, step=1)
        # corrupt the npz
        d = os.path.join(str(tmp_path), "step_0000000001")
        path = os.path.join(d, "arrays.npz")
        data = bytearray(open(path, "rb").read())
        data[len(data) // 2] ^= 0xFF
        open(path, "wb").write(bytes(data))
        with pytest.raises(Exception):
            ck.restore()

    def test_prune_keeps_latest(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=False, keep=2)
        for s in (1, 2, 3, 4):
            ck.save({"x": np.asarray([s])}, step=s)
        assert ck.steps() == [3, 4]
        assert int(ck.restore()["x"][0]) == 4

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=True)
        ck.save({"x": np.arange(5)}, step=1)
        ck.wait()
        assert ck.latest_step() == 1


class TestFaultTolerance:
    def test_kill_restart_bit_exact(self, tmp_path):
        """Run 6 epochs straight vs 3 epochs + 'crash' + restore + 3 more:
        identical final population (deterministic restart)."""
        ck_dir = str(tmp_path / "ck")
        ref = GAEngine(_cfg(), sphere)
        pop_ref, _ = ref.run(epochs=6)

        e1 = GAEngine(_cfg(), sphere,
                      checkpointer=Checkpointer(ck_dir, async_write=False),
                      checkpoint_every=1)
        e1.run(epochs=3)
        # simulate crash: new engine process restores from checkpoint
        e2 = GAEngine(_cfg(), sphere,
                      checkpointer=Checkpointer(ck_dir, async_write=False),
                      checkpoint_every=1)
        pop2 = e2.restore()
        assert pop2 is not None
        assert int(jnp.asarray(pop2.epoch)) == 3
        pop2 = jax.tree_util.tree_map(jnp.asarray, pop2)
        pop_resumed, _ = e2.run(pop2, epochs=3)
        np.testing.assert_array_equal(np.asarray(pop_ref.genomes),
                                      np.asarray(pop_resumed.genomes))
        np.testing.assert_array_equal(np.asarray(pop_ref.fitness),
                                      np.asarray(pop_resumed.fitness))

    def test_train_resume(self, tmp_path):
        from repro.launch.train import train
        logs = []
        ck = str(tmp_path / "t")
        train(steps=6, batch=2, seq=16, ckpt_dir=ck, ckpt_every=3,
              log_every=2, log_fn=logs.append)
        # resume continues from step 6 checkpoint
        logs2 = []
        state, hist = train(steps=8, batch=2, seq=16, ckpt_dir=ck,
                            ckpt_every=3, log_every=1, log_fn=logs2.append)
        assert any("resumed from step 6" in str(l) for l in logs2)
        assert hist[-1]["step"] == 8


class TestElastic:
    def test_shrink_preserves_best(self):
        cfg = _cfg(num_islands=4)
        eng = GAEngine(cfg, sphere)
        pop = eng.init()
        best = float(jnp.min(pop.fitness))
        small = repartition_islands(cfg, pop, 2, jax.random.PRNGKey(1))
        assert small.genomes.shape[0] == 2
        assert float(jnp.min(small.fitness)) == best

    def test_grow_preserves_best_and_marks_reeval(self):
        cfg = _cfg(num_islands=2)
        eng = GAEngine(cfg, sphere)
        pop = eng.init()
        best = float(jnp.min(pop.fitness))
        big = repartition_islands(cfg, pop, 4, jax.random.PRNGKey(1))
        assert big.genomes.shape[0] == 4
        assert float(jnp.min(big.fitness)) == best
        # clones need re-evaluation (inf fitness)
        assert bool(jnp.any(jnp.isinf(big.fitness)))

    def test_resume_on_resized_mesh_runs(self):
        cfg = _cfg(num_islands=2)
        eng = GAEngine(cfg, sphere)
        pop = eng.init()
        big = repartition_islands(cfg, pop, 4, jax.random.PRNGKey(1))
        cfg4 = _cfg(num_islands=4)
        eng4 = GAEngine(cfg4, sphere)
        from repro.core.island import evaluate_population
        big = eng4._init_eval(big._replace(
            fitness=jnp.full_like(big.fitness, jnp.inf)))
        pop_out, hist = eng4.run(big, epochs=2)
        assert pop_out.genomes.shape[0] == 4


class TestElasticLaneRebalance:
    """GAEngine.resize: mid-run repartition + broker lane re-balance (the
    ROADMAP's 'elastic re-balance on mesh resize')."""

    def test_rebalanced_lanes_match_fixed_lane_run(self):
        """Acceptance: resizing islands mid-run re-balances lanes without
        retracing errors, and — dispatch permutations never change fitness
        values — tracks the fixed-lane run bit-exactly on a deterministic
        benchmark."""
        cost_fn = lambda g: jnp.sum(jnp.abs(g), -1) + 0.1

        def run_schedule(workers_after):
            eng = GAEngine(_cfg(num_islands=4), sphere, cost_fn=cost_fn,
                           num_workers=8)
            pop = eng.init()
            pop, h1 = eng.run(pop, epochs=2)
            pop = eng.resize(pop, 2, rng=jax.random.PRNGKey(9),
                             num_workers=workers_after)
            pop, h2 = eng.run(pop, epochs=2)
            return eng, pop, h1 + h2

        eng_a, pop_a, hist_a = run_schedule(None)    # re-balanced lanes
        eng_b, pop_b, hist_b = run_schedule(8)       # lanes kept fixed
        assert eng_a.broker.num_workers == 4         # 8 * 2/4
        assert eng_b.broker.num_workers == 8
        assert hist_a[-1]["best"] == hist_b[-1]["best"]
        np.testing.assert_array_equal(np.asarray(pop_a.genomes),
                                      np.asarray(pop_b.genomes))
        # cost-balanced dispatch stayed engaged through the resize
        assert all(h["balanced"] == 1.0 for h in hist_a)
        assert pop_a.genomes.shape[0] == 2

    def test_grow_reevaluates_clones_and_scales_lanes(self):
        eng = GAEngine(_cfg(num_islands=2), sphere,
                       cost_fn=lambda g: jnp.sum(jnp.abs(g), -1) + 0.1,
                       num_workers=4)
        pop = eng.init()
        pop, _ = eng.run(pop, epochs=1)
        evals_before = eng.evals_host
        pop = eng.resize(pop, 4, rng=jax.random.PRNGKey(3))
        assert pop.genomes.shape[0] == 4
        assert eng.broker.num_workers == 8
        # clones were re-evaluated (no +inf left) and counted
        assert bool(jnp.all(jnp.isfinite(pop.fitness)))
        assert eng.evals_host == evals_before + eng.cfg.global_pop
        pop, hist = eng.run(pop, epochs=1)
        assert all(h["balanced"] == 1.0 for h in hist)
        assert bool(jnp.all(jnp.isfinite(pop.fitness)))


class TestStraggler:
    def test_backup_eval_identical_fitness(self):
        genomes = jax.random.uniform(jax.random.PRNGKey(0), (64, 4))
        cost = jnp.sum(genomes, -1)
        fit, stats = backup_dispatch_eval(sphere, genomes, cost,
                                          num_workers=8, backup_frac=0.25)
        np.testing.assert_allclose(np.asarray(fit),
                                   np.asarray(sphere(genomes)), rtol=1e-6)
        assert stats["duplicated"] >= 8

    def test_backup_eval_non_divisible_population(self):
        """Total dispatch: speculative backups work when N % W != 0."""
        genomes = jax.random.uniform(jax.random.PRNGKey(4), (53, 4))
        cost = jnp.sum(genomes, -1)
        fit, stats = backup_dispatch_eval(sphere, genomes, cost,
                                          num_workers=8, backup_frac=0.2)
        np.testing.assert_allclose(np.asarray(fit),
                                   np.asarray(sphere(genomes)), rtol=1e-6)
        assert stats["duplicated"] % 8 == 0


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 60),
    w=st.integers(1, 12),
    frac=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**30),
)
def test_backup_dispatch_property_any_shape(n, w, frac, seed):
    """Speculative backup dispatch over random N/W (odd N, N < W): the
    combined fitness is identical to direct evaluation and the duplicate
    batch stays a lane-divisible size."""
    genomes = jnp.asarray(
        np.random.default_rng(seed).uniform(-1, 1, (n, 3)), jnp.float32)
    cost = jnp.sum(jnp.abs(genomes), -1) + 0.05
    fit, stats = backup_dispatch_eval(sphere, genomes, cost,
                                      num_workers=w, backup_frac=frac)
    np.testing.assert_allclose(np.asarray(fit),
                               np.asarray(sphere(genomes)), rtol=1e-6)
    assert stats["duplicated"] % w == 0
    assert stats["duplicated"] >= w


class TestEvalsCounter:
    def test_evals_counter_is_exact_past_f32_range(self, tmp_path):
        """f32 loses exact integer counts past 2^24 (~16.7M — one
        3,500-core epoch); the int counter must round-trip exactly."""
        from repro.core.population import evals_dtype, init_population
        cfg = _cfg()
        pop = init_population(cfg, jax.random.PRNGKey(0))
        assert jnp.issubdtype(pop.evals.dtype, jnp.integer)
        big = 2 ** 24 + 1                       # not representable in f32
        pop = pop._replace(evals=jnp.asarray(big, evals_dtype()))
        assert int(pop.evals + 1) == big + 1    # f32 would stay at 2^24
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(dict(pop._asdict()), step=1)
        eng = GAEngine(_cfg(), sphere, checkpointer=ck)
        restored = eng.restore()
        assert int(restored.evals) == big
        assert jnp.issubdtype(jnp.asarray(restored.evals).dtype, jnp.integer)

    def test_host_counter_exact_past_i32_wrap(self):
        """The device counter is i32 without x64 and wraps at 2^31 (~128
        epochs at 3,500-core scale); the engine's host-side accumulator
        must stay exact across the wrap."""
        from repro.core.population import evals_dtype
        cfg = _cfg(num_epochs=1)
        eng = GAEngine(cfg, sphere)
        pop = eng.init()
        near = 2**31 - 50                       # below i32 max
        pop = pop._replace(evals=jnp.asarray(near, evals_dtype()))
        eng.evals_host = 0                      # force reseed from device
        pop, _ = eng.run(pop, epochs=1)
        inc = (cfg.generations_per_epoch * cfg.num_islands
               * cfg.pop_per_island)
        assert eng.evals_host == near + inc     # exact, past 2^31 - 1
        assert eng.evals_host > 2**31 - 1
        if not jax.config.jax_enable_x64:
            # the i32 device counter wrapped and cannot agree
            assert int(jax.device_get(pop.evals)) != eng.evals_host

    def test_host_counter_checkpoint_roundtrip(self, tmp_path):
        """evals_host rides along the device counter in checkpoints and
        restores exactly (u64 range)."""
        big = 5_000_000_000                     # > 2^32
        ck = Checkpointer(str(tmp_path), async_write=False)
        cfg = _cfg()
        eng = GAEngine(cfg, sphere, checkpointer=ck, checkpoint_every=1)
        pop = eng.init()
        eng.evals_host = big
        pop, _ = eng.run(pop, epochs=1)
        inc = (cfg.generations_per_epoch * cfg.num_islands
               * cfg.pop_per_island)
        assert eng.evals_host == big + inc
        eng2 = GAEngine(cfg, sphere, checkpointer=ck)
        restored = eng2.restore()
        assert restored is not None
        assert eng2.evals_host == big + inc

    def test_engine_counts_match_device_pre_wrap(self):
        eng = GAEngine(_cfg(), sphere)
        pop, _ = eng.run(epochs=3)
        assert eng.evals_host == int(jax.device_get(pop.evals))

    def test_restore_upgrades_legacy_float_counter(self, tmp_path):
        """Pre-int checkpoints stored evals as f32; restore normalizes."""
        cfg = _cfg()
        eng = GAEngine(cfg, sphere,
                       checkpointer=Checkpointer(str(tmp_path),
                                                 async_write=False))
        pop = eng.init()
        state = dict(pop._asdict())
        state["evals"] = np.float32(float(np.asarray(pop.evals)))
        eng.checkpointer.save(state, step=1)
        restored = eng.restore()
        assert jnp.issubdtype(jnp.asarray(restored.evals).dtype, jnp.integer)
        # and the restored population steps fine (dtype matches the jitted
        # epoch step's expectations)
        out, _ = eng.run(jax.tree_util.tree_map(jnp.asarray, restored),
                         epochs=1)
        assert int(out.evals) > int(restored.evals)
