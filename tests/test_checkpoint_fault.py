"""Checkpoint/restart + fault tolerance + elasticity + stragglers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs.base import GAConfig
from repro.core.engine import GAEngine
from repro.fitness import sphere
from repro.runtime.elastic import repartition_islands
from repro.runtime.straggler import backup_dispatch_eval


def _cfg(**kw):
    base = dict(num_genes=5, pop_per_island=16, num_islands=4,
                generations_per_epoch=2, num_epochs=6, lower=-2.0,
                upper=2.0, fused_operators=False, seed=3)
    base.update(kw)
    return GAConfig(**base)


class TestCheckpointer:
    def test_roundtrip_bit_exact(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=False)
        state = {"a": np.arange(10, dtype=np.float32),
                 "nest": {"b": np.ones((3, 4), np.int32),
                          "c": np.float64(3.5)}}
        ck.save(state, step=7)
        out = ck.restore()
        np.testing.assert_array_equal(out["a"], state["a"])
        np.testing.assert_array_equal(out["nest"]["b"], state["nest"]["b"])
        assert float(out["nest"]["c"]) == 3.5

    def test_corruption_detected(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save({"a": np.arange(100, dtype=np.float32)}, step=1)
        # corrupt the npz
        d = os.path.join(str(tmp_path), "step_0000000001")
        path = os.path.join(d, "arrays.npz")
        data = bytearray(open(path, "rb").read())
        data[len(data) // 2] ^= 0xFF
        open(path, "wb").write(bytes(data))
        with pytest.raises(Exception):
            ck.restore()

    def test_prune_keeps_latest(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=False, keep=2)
        for s in (1, 2, 3, 4):
            ck.save({"x": np.asarray([s])}, step=s)
        assert ck.steps() == [3, 4]
        assert int(ck.restore()["x"][0]) == 4

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=True)
        ck.save({"x": np.arange(5)}, step=1)
        ck.wait()
        assert ck.latest_step() == 1


class TestFaultTolerance:
    def test_kill_restart_bit_exact(self, tmp_path):
        """Run 6 epochs straight vs 3 epochs + 'crash' + restore + 3 more:
        identical final population (deterministic restart)."""
        ck_dir = str(tmp_path / "ck")
        ref = GAEngine(_cfg(), sphere)
        pop_ref, _ = ref.run(epochs=6)

        e1 = GAEngine(_cfg(), sphere,
                      checkpointer=Checkpointer(ck_dir, async_write=False),
                      checkpoint_every=1)
        e1.run(epochs=3)
        # simulate crash: new engine process restores from checkpoint
        e2 = GAEngine(_cfg(), sphere,
                      checkpointer=Checkpointer(ck_dir, async_write=False),
                      checkpoint_every=1)
        pop2 = e2.restore()
        assert pop2 is not None
        assert int(jnp.asarray(pop2.epoch)) == 3
        pop2 = jax.tree_util.tree_map(jnp.asarray, pop2)
        pop_resumed, _ = e2.run(pop2, epochs=3)
        np.testing.assert_array_equal(np.asarray(pop_ref.genomes),
                                      np.asarray(pop_resumed.genomes))
        np.testing.assert_array_equal(np.asarray(pop_ref.fitness),
                                      np.asarray(pop_resumed.fitness))

    def test_train_resume(self, tmp_path):
        from repro.launch.train import train
        logs = []
        ck = str(tmp_path / "t")
        train(steps=6, batch=2, seq=16, ckpt_dir=ck, ckpt_every=3,
              log_every=2, log_fn=logs.append)
        # resume continues from step 6 checkpoint
        logs2 = []
        state, hist = train(steps=8, batch=2, seq=16, ckpt_dir=ck,
                            ckpt_every=3, log_every=1, log_fn=logs2.append)
        assert any("resumed from step 6" in str(l) for l in logs2)
        assert hist[-1]["step"] == 8


class TestElastic:
    def test_shrink_preserves_best(self):
        cfg = _cfg(num_islands=4)
        eng = GAEngine(cfg, sphere)
        pop = eng.init()
        best = float(jnp.min(pop.fitness))
        small = repartition_islands(cfg, pop, 2, jax.random.PRNGKey(1))
        assert small.genomes.shape[0] == 2
        assert float(jnp.min(small.fitness)) == best

    def test_grow_preserves_best_and_marks_reeval(self):
        cfg = _cfg(num_islands=2)
        eng = GAEngine(cfg, sphere)
        pop = eng.init()
        best = float(jnp.min(pop.fitness))
        big = repartition_islands(cfg, pop, 4, jax.random.PRNGKey(1))
        assert big.genomes.shape[0] == 4
        assert float(jnp.min(big.fitness)) == best
        # clones need re-evaluation (inf fitness)
        assert bool(jnp.any(jnp.isinf(big.fitness)))

    def test_resume_on_resized_mesh_runs(self):
        cfg = _cfg(num_islands=2)
        eng = GAEngine(cfg, sphere)
        pop = eng.init()
        big = repartition_islands(cfg, pop, 4, jax.random.PRNGKey(1))
        cfg4 = _cfg(num_islands=4)
        eng4 = GAEngine(cfg4, sphere)
        from repro.core.island import evaluate_population
        big = eng4._init_eval(big._replace(
            fitness=jnp.full_like(big.fitness, jnp.inf)))
        pop_out, hist = eng4.run(big, epochs=2)
        assert pop_out.genomes.shape[0] == 4


class TestStraggler:
    def test_backup_eval_identical_fitness(self):
        genomes = jax.random.uniform(jax.random.PRNGKey(0), (64, 4))
        cost = jnp.sum(genomes, -1)
        fit, stats = backup_dispatch_eval(sphere, genomes, cost,
                                          num_workers=8, backup_frac=0.25)
        np.testing.assert_allclose(np.asarray(fit),
                                   np.asarray(sphere(genomes)), rtol=1e-6)
        assert stats["duplicated"] >= 8

    def test_backup_eval_non_divisible_population(self):
        """Total dispatch: speculative backups work when N % W != 0."""
        genomes = jax.random.uniform(jax.random.PRNGKey(4), (53, 4))
        cost = jnp.sum(genomes, -1)
        fit, stats = backup_dispatch_eval(sphere, genomes, cost,
                                          num_workers=8, backup_frac=0.2)
        np.testing.assert_allclose(np.asarray(fit),
                                   np.asarray(sphere(genomes)), rtol=1e-6)
        assert stats["duplicated"] % 8 == 0


class TestEvalsCounter:
    def test_evals_counter_is_exact_past_f32_range(self, tmp_path):
        """f32 loses exact integer counts past 2^24 (~16.7M — one
        3,500-core epoch); the int counter must round-trip exactly."""
        from repro.core.population import evals_dtype, init_population
        cfg = _cfg()
        pop = init_population(cfg, jax.random.PRNGKey(0))
        assert jnp.issubdtype(pop.evals.dtype, jnp.integer)
        big = 2 ** 24 + 1                       # not representable in f32
        pop = pop._replace(evals=jnp.asarray(big, evals_dtype()))
        assert int(pop.evals + 1) == big + 1    # f32 would stay at 2^24
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(dict(pop._asdict()), step=1)
        eng = GAEngine(_cfg(), sphere, checkpointer=ck)
        restored = eng.restore()
        assert int(restored.evals) == big
        assert jnp.issubdtype(jnp.asarray(restored.evals).dtype, jnp.integer)

    def test_restore_upgrades_legacy_float_counter(self, tmp_path):
        """Pre-int checkpoints stored evals as f32; restore normalizes."""
        cfg = _cfg()
        eng = GAEngine(cfg, sphere,
                       checkpointer=Checkpointer(str(tmp_path),
                                                 async_write=False))
        pop = eng.init()
        state = dict(pop._asdict())
        state["evals"] = np.float32(float(np.asarray(pop.evals)))
        eng.checkpointer.save(state, step=1)
        restored = eng.restore()
        assert jnp.issubdtype(jnp.asarray(restored.evals).dtype, jnp.integer)
        # and the restored population steps fine (dtype matches the jitted
        # epoch step's expectations)
        out, _ = eng.run(jax.tree_util.tree_map(jnp.asarray, restored),
                         epochs=1)
        assert int(out.evals) > int(restored.evals)
