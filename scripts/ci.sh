#!/usr/bin/env bash
# CI entrypoint. Usage:
#   scripts/ci.sh         # full tier-1 lane (everything, incl. slow)
#   scripts/ci.sh fast    # fast lane: skips @pytest.mark.slow subprocess tests
set -euo pipefail
cd "$(dirname "$0")/.."

# dev deps are optional (tests shim hypothesis when absent); install when
# a network/package index is available, continue otherwise
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "ci.sh: dev requirements unavailable, using bundled fallbacks"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

LANE="${1:-full}"
case "$LANE" in
    fast) exec python -m pytest -x -q -m "not slow" ;;
    full) exec python -m pytest -x -q ;;
    *)    echo "unknown lane: $LANE (want: fast|full)" >&2; exit 2 ;;
esac
