#!/usr/bin/env bash
# CI entrypoint. Usage:
#   scripts/ci.sh                 # full tier-1 lane (everything, incl. slow)
#   scripts/ci.sh fast            # lint + verify-protocol, then skip-slow tests
#   scripts/ci.sh durations       # fast-lane tests + the 15 slowest listed
#   scripts/ci.sh lint            # protocol linter + ruff, no test suites
#   scripts/ci.sh verify-protocol # broker-contract model check, no tests
#   scripts/ci.sh sanitize        # dynamic thread sanitizer, no tests
#   scripts/ci.sh obs-smoke       # metrics bus + exporter smoke, no tests
#   scripts/ci.sh netbroker-smoke # socket broker end-to-end smoke, no tests
#
# The verify-protocol lane model-checks the broker queue contract
# (src/repro/analysis/proto/): a bounded, deterministic (BFS order,
# fixed spec) exhaustive sweep over every interleaving of 2 workers x
# 2 tasks with a delivery re-queue and a crash injection, checking the
# contract invariants in every reached state and printing the states
# explored. A violation prints the minimal counterexample schedule and
# exits 1; a sweep truncated by the wall-time cap exits 3 — never
# silently passing. It runs in the fast lane right after lint, before
# any test suite: a protocol regression fails in seconds. The
# `--exhaustive` sweep (unbounded) is NOT run here — the slow-marked
# test in tests/test_proto_model.py covers the full CI-bound sweep and
# tests/test_proto_replay.py replays model counterexample schedules
# against the real mq.py in tier-1 (covered by the durations lane).
#
# The sanitize lane runs the dynamic thread sanitizer
# (src/repro/analysis/sanitize/): real runtime scenarios — queue
# dispatch, multitenant fleet sharing, the autoscaler, CostEMA, host
# pool, batch spool — under instrumented threading with hybrid
# lockset + happens-before race detection, a FIXED seed set (base seed
# 0, 3 PCT interleavings per schedulable scenario; a racy schedule
# replays bit-identically from its seed), a per-schedule wall cap
# (exit 3 when truncated, never a silent pass), and per-site OSError
# fault injection asserting the model checker's invariants on a live
# broker tree. It prints the schedules explored and runs in the fast
# lane right after verify-protocol: a race regression in runtime/
# fails in seconds, before any test suite starts.
#
# The lint lane runs the protocol linter (`python -m repro.analysis src`
# — atomic-write discipline, worker import purity, trace purity, lock
# hygiene; see src/repro/analysis/) and, when installed, ruff with the
# conservative rule set pinned in pyproject.toml. The fast lane runs
# lint FIRST: a queue-protocol regression fails in seconds, before any
# test suite starts. ruff is pinned in requirements-dev.txt but absent
# from the hermetic runtime container, so its step degrades to a notice
# rather than a failure when the index is unreachable; the custom pass
# has no dependencies and always runs.
#
# The netbroker-smoke lane boots an in-process socket BrokerServer,
# attaches a thread-mode NetWorkerPool plus a SocketQueueBackend over
# TCP (`python -m repro.runtime.netbroker --smoke`), evaluates a real
# batch end to end, and asserts the queue drained to done — tasks,
# claimed, results, and runs all empty after close. A broken frame
# codec, RPC handler, or worker loop fails in seconds; it runs in the
# fast lane right after obs-smoke, before any test suite starts.
#
# The fast lane names tests/backend_conformance.py FIRST: the unified
# DispatchBackend contract suite (eager/jit parity, padded-broker
# compose, pickled fitness, drain-before-close, timeout -> re-queue ->
# retry) parametrized over all five decoupled backends — HostPool,
# slurm-mock, k8s-mock, and the message queue over BOTH its transports
# (file broker and socket broker) — so a contract regression fails
# before the backend-specific suites start. (pytest de-duplicates the
# explicit path against the tests/ directory collection.)
#
# Multi-tenant + elastic mq coverage (all thread-mode, fast lane):
#   tests/test_mq_multitenant.py — two concurrent ga_run invocations
#     sharing ONE worker fleet finish bit-identical to dedicated-fleet
#     runs at --genes 1; cross-run priority claim order (deterministic
#     prefix + >= counts, no == timing asserts); per-run close leaves a
#     shared fleet alive; run-aware GC never sweeps another run's files.
#   tests/test_mq_properties.py — queue chaos/property sweeps via the
#     hypothesis stub: task-name parse round-trip, barrier-raced
#     single-winner claims, monotone delivery bumps that never burn the
#     retry budget, first-result-wins under late superseded duplicates.
#   tests/test_mq.py — queue protocol, lease liveness, streaming CostEMA,
#     GC bounds, FleetAutoscaler grow-on-depth / shrink-on-drain, poison
#     STOP tickets honored at chunk boundaries, and the in-process
#     `ga_run --dispatch-backend mq-mock` e2e (bit-identical to inline).
# Only multi-second subprocess e2e tests (SLURM / k8s-mock array-task
# and persistent mq worker interpreter spawns, multidevice runs) are
# @pytest.mark.slow and deferred to the full lane.
#
# The durations lane prints `pytest --durations=15` so timing-sensitive
# dispatch tests that are drifting toward their timeout floors get
# flagged BEFORE they start flaking on a loaded box.
set -euo pipefail
cd "$(dirname "$0")/.."

# dev deps are optional (tests shim hypothesis when absent); install when
# a network/package index is available, continue otherwise
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "ci.sh: dev requirements unavailable, using bundled fallbacks"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

run_lint() {
    python -m repro.analysis src
    if python -c "import ruff" 2>/dev/null; then
        python -m ruff check src tests scripts
    elif command -v ruff >/dev/null 2>&1; then
        ruff check src tests scripts
    else
        echo "ci.sh: ruff unavailable, ran protocol linter only"
    fi
}

run_verify_protocol() {
    python -m repro.analysis --protocol \
        --workers 2 --tasks 2 --wall-time 120
}

run_sanitize() {
    python -m repro.analysis --sanitize \
        --seed 0 --schedules 3 --wall-time 30 --fault-inject
}

# Observability smoke: a real mq-mock dispatch with the metrics bus
# installed — asserts the claim/publish counters, event-log kinds, and
# replayed queue depth, then writes + parses the Prometheus textfile
# (see repro/obs/__main__.py). Catches a broken emission site or
# exporter in seconds, before the test suites start.
run_obs_smoke() {
    python -m repro.obs --smoke
}

# Socket broker smoke: in-process BrokerServer + thread NetWorkerPool +
# SocketQueueBackend over real TCP, asserts drain-to-done (see
# repro/runtime/netbroker.py `_smoke`).
run_netbroker_smoke() {
    python -m repro.runtime.netbroker --smoke
}

LANE="${1:-full}"
case "$LANE" in
    lint)      run_lint ;;
    verify-protocol) run_verify_protocol ;;
    sanitize)  run_sanitize ;;
    obs-smoke) run_obs_smoke ;;
    netbroker-smoke) run_netbroker_smoke ;;
    fast)      run_lint
               run_verify_protocol
               run_sanitize
               run_obs_smoke
               run_netbroker_smoke
               exec python -m pytest -x -q -m "not slow" \
                    tests/backend_conformance.py tests ;;
    durations) exec python -m pytest -q -m "not slow" --durations=15 \
                    tests/backend_conformance.py tests ;;
    full)      exec python -m pytest -x -q ;;
    *)         echo "unknown lane: $LANE" >&2
               echo "want: fast|durations|full|lint|verify-protocol|sanitize|obs-smoke|netbroker-smoke" >&2
               exit 2 ;;
esac
