#!/usr/bin/env bash
# CI entrypoint. Usage:
#   scripts/ci.sh            # full tier-1 lane (everything, incl. slow)
#   scripts/ci.sh fast       # lint, then skip-@pytest.mark.slow tests
#   scripts/ci.sh durations  # fast lane + the 15 slowest tests listed
#   scripts/ci.sh lint       # protocol linter + ruff, no test suites
#
# The lint lane runs the protocol linter (`python -m repro.analysis src`
# — atomic-write discipline, worker import purity, trace purity, lock
# hygiene; see src/repro/analysis/) and, when installed, ruff with the
# conservative rule set pinned in pyproject.toml. The fast lane runs
# lint FIRST: a queue-protocol regression fails in seconds, before any
# test suite starts. ruff is pinned in requirements-dev.txt but absent
# from the hermetic runtime container, so its step degrades to a notice
# rather than a failure when the index is unreachable; the custom pass
# has no dependencies and always runs.
#
# The fast lane names tests/backend_conformance.py FIRST: the unified
# DispatchBackend contract suite (eager/jit parity, padded-broker
# compose, pickled fitness, drain-before-close, timeout -> re-queue ->
# retry) parametrized over all four decoupled backends — HostPool,
# slurm-mock, k8s-mock, and the message queue — so a contract regression
# fails before the backend-specific suites start. (pytest de-duplicates
# the explicit path against the tests/ directory collection.)
#
# Multi-tenant + elastic mq coverage (all thread-mode, fast lane):
#   tests/test_mq_multitenant.py — two concurrent ga_run invocations
#     sharing ONE worker fleet finish bit-identical to dedicated-fleet
#     runs at --genes 1; cross-run priority claim order (deterministic
#     prefix + >= counts, no == timing asserts); per-run close leaves a
#     shared fleet alive; run-aware GC never sweeps another run's files.
#   tests/test_mq_properties.py — queue chaos/property sweeps via the
#     hypothesis stub: task-name parse round-trip, barrier-raced
#     single-winner claims, monotone delivery bumps that never burn the
#     retry budget, first-result-wins under late superseded duplicates.
#   tests/test_mq.py — queue protocol, lease liveness, streaming CostEMA,
#     GC bounds, FleetAutoscaler grow-on-depth / shrink-on-drain, poison
#     STOP tickets honored at chunk boundaries, and the in-process
#     `ga_run --dispatch-backend mq-mock` e2e (bit-identical to inline).
# Only multi-second subprocess e2e tests (SLURM / k8s-mock array-task
# and persistent mq worker interpreter spawns, multidevice runs) are
# @pytest.mark.slow and deferred to the full lane.
#
# The durations lane prints `pytest --durations=15` so timing-sensitive
# dispatch tests that are drifting toward their timeout floors get
# flagged BEFORE they start flaking on a loaded box.
set -euo pipefail
cd "$(dirname "$0")/.."

# dev deps are optional (tests shim hypothesis when absent); install when
# a network/package index is available, continue otherwise
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "ci.sh: dev requirements unavailable, using bundled fallbacks"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

run_lint() {
    python -m repro.analysis src
    if python -c "import ruff" 2>/dev/null; then
        python -m ruff check src tests scripts
    elif command -v ruff >/dev/null 2>&1; then
        ruff check src tests scripts
    else
        echo "ci.sh: ruff unavailable, ran protocol linter only"
    fi
}

LANE="${1:-full}"
case "$LANE" in
    lint)      run_lint ;;
    fast)      run_lint
               exec python -m pytest -x -q -m "not slow" \
                    tests/backend_conformance.py tests ;;
    durations) exec python -m pytest -q -m "not slow" --durations=15 \
                    tests/backend_conformance.py tests ;;
    full)      exec python -m pytest -x -q ;;
    *)         echo "unknown lane: $LANE (want: fast|durations|full|lint)" >&2
               exit 2 ;;
esac
