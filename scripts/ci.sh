#!/usr/bin/env bash
# CI entrypoint. Usage:
#   scripts/ci.sh         # full tier-1 lane (everything, incl. slow)
#   scripts/ci.sh fast    # fast lane: skips @pytest.mark.slow subprocess tests
#
# The fast lane includes the batch-dispatch (mock-scheduler) conformance
# tests: tests/test_batchq.py runs the spool/timeout/re-queue machinery on
# thread-mode LocalMockScheduler workers in-process, and the Kubernetes
# path (KubernetesScheduler against the in-process MockKubectl runner:
# command construction + full submit->poll->result conformance, spool GC,
# cost-sized chunking) without needing a cluster. It also includes the
# message-queue subsystem (tests/test_mq.py): the shared DispatchBackend
# conformance suite over QueueBackend, lease-expiry -> re-queue, streaming
# CostEMA, broker-directory GC bounds, a Scheduler-launched fleet, and an
# in-process `ga_run --dispatch-backend mq-mock` e2e checked bit-identical
# against InlineBackend — all on thread-mode workers. Only multi-second
# subprocess e2e tests (SLURM / k8s-mock array-task and persistent mq
# worker interpreter spawns, multidevice runs) are @pytest.mark.slow and
# deferred to the full lane.
set -euo pipefail
cd "$(dirname "$0")/.."

# dev deps are optional (tests shim hypothesis when absent); install when
# a network/package index is available, continue otherwise
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "ci.sh: dev requirements unavailable, using bundled fallbacks"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

LANE="${1:-full}"
case "$LANE" in
    fast) exec python -m pytest -x -q -m "not slow" ;;
    full) exec python -m pytest -x -q ;;
    *)    echo "unknown lane: $LANE (want: fast|full)" >&2; exit 2 ;;
esac
