"""Paper Fig. 6: meta-GA hyperparameter evolution.

A governing GA (I=3 islands) evolves (P, mu_cx, mu_mut, eta_m, eta_sbx)
per Tab. 4; each individual's fitness is the best of `num_seeds` inner GA
runs. Prints per-epoch population statistics of each hyperparameter — the
analogue of the paper's mean/std/min/max trajectories.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GAConfig
from repro.core.engine import GAEngine
from repro.core.meta import (META_GENE_SPEC, make_meta_fitness,
                             meta_ga_config)
from repro.fitness import rastrigin


def run(csv: bool = True, *, epochs: int = 2, pop: int = 8,
        inner_generations: int = 6, num_seeds: int = 2):
    inner_cfg = GAConfig(num_genes=6, lower=-5.12, upper=5.12,
                         fused_operators=False)
    meta_fit = make_meta_fitness(inner_cfg, rastrigin, p_max=24,
                                 generations=inner_generations,
                                 num_seeds=num_seeds)
    mcfg = meta_ga_config(num_epochs=epochs, pop_per_island=pop,
                          num_islands=3)
    eng = GAEngine(mcfg, jax.jit(meta_fit))
    pop_state = eng.init()
    rows = []
    for e in range(epochs):
        pop_state, _ = eng._epoch_step(pop_state)
        g = np.asarray(jax.device_get(pop_state.genomes)).reshape(-1, 5)
        stats = {}
        for i, (name, lo, hi) in enumerate(META_GENE_SPEC):
            stats[name] = (g[:, i].mean(), g[:, i].std(),
                           g[:, i].min(), g[:, i].max())
        rows.append((e, stats))
        if csv:
            line = ",".join(f"{k}={v[0]:.2f}+-{v[1]:.2f}"
                            for k, v in stats.items())
            print(f"fig6_metaga,epoch={e},{line}")
    gbest, fbest = eng.best(pop_state)
    if csv:
        print(f"fig6_metaga,best_hyper={np.round(gbest, 3).tolist()},"
              f"best_inner_fitness={fbest[0]:.4f}")
    return rows


if __name__ == "__main__":
    run()
