"""Roofline analysis (deliverable (g)) from the dry-run's compiled
artifacts (experiments/dryrun.jsonl).

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs_per_chip / peak_FLOPs        [s]
    memory term     = HLO_bytes_per_chip / HBM_bw            [s]
    collective term = coll_bytes_per_chip / ICI link_bw      [s]

(The per-chip form is equivalent to the global form divided by chips.)
HLO numbers use the depth-probe-corrected values (XLA cost analysis counts
a scan body once; dryrun.py extrapolates from unrolled 1/2-period probes).

MODEL_FLOPS (the "useful" flops): 6*N_active*D for train, 2*N_active*D for
prefill/decode (D = tokens processed globally). The ratio
MODEL_FLOPS / HLO_FLOPs exposes remat recompute, causal-block waste,
sharding-padding waste and MoE dispatch overhead.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI
(conservative single-link figure; the v5e 2D torus has 4 links/chip, so
ring-based collectives can beat this term by up to 4x).
"""
from __future__ import annotations

import json
import os
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (1 link modeled)

SHAPE_TOKENS = {
    "train_4k":    4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k":  1 * 128,
    "long_500k":   1 * 1,
}


def model_flops(rec: dict) -> float:
    n = rec.get("active_params") or 0
    tokens = SHAPE_TOKENS.get(rec["shape"], 0)
    mult = 6 if rec["shape"].startswith("train") else 2
    return float(mult * n * tokens)


def analyze_record(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    cost = rec.get("cost") or {}
    flops = rec.get("flops_corrected") or cost.get("flops") or 0.0
    mem_bytes = (rec.get("bytes_accessed_corrected")
                 or cost.get("bytes_accessed") or 0.0)
    coll = rec.get("coll_bytes_corrected")
    if coll is None:
        coll = rec.get("coll_bytes") or 0.0
    # the depth-probe linear extrapolation can undershoot when a one-off
    # reshard lands in the d1 probe; clamp at the single-count raw value
    coll = max(coll, rec.get("coll_bytes") or 0.0, 0.0)
    flops = max(flops, cost.get("flops") or 0.0)
    mem_bytes = max(mem_bytes, cost.get("bytes_accessed") or 0.0)
    chips = rec.get("chips", 256)

    t_comp = flops / PEAK_FLOPS
    t_mem = mem_bytes / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = max(terms.values())
    mf = model_flops(rec)
    useful_ratio = mf / (flops * chips) if flops else 0.0
    # roofline fraction: useful model flops per chip-second at the bound
    roofline_frac = ((mf / chips) / PEAK_FLOPS) / t_bound if t_bound else 0.0
    mem = rec.get("mem") or {}
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec.get("variant", "baseline"),
        "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": flops * chips,
        "useful_ratio": useful_ratio,
        "roofline_frac": roofline_frac,
        "peak_arg_bytes": mem.get("argument_bytes"),
        "temp_bytes": mem.get("temp_bytes"),
        "microbatches": rec.get("microbatches"),
    }


def load(path: str = "experiments/dryrun.jsonl") -> list:
    recs = {}
    if not os.path.exists(path):
        return []
    for line in open(path):
        r = json.loads(line)
        key = (r["arch"], r["shape"], r["mesh"],
               r.get("variant", "baseline"))
        recs[key] = r                       # last write wins
    return [r for r in recs.values()]


def suggest(row: dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("reduce resharding: align param/activation shardings or "
                "overlap the gather/reduce with the layer scan")
    if d == "memory":
        if not row["shape"].startswith("train"):
            return ("decode/prefill is weight+cache-bound: quantize KV "
                    "cache or increase batch to amortize weight reads")
        return "raise arithmetic intensity: larger microbatch or fused ops"
    if row["useful_ratio"] < 0.4:
        return ("compute-bound but low useful ratio: cut remat recompute "
                "/ causal-block waste / padding from uneven sharding")
    return "near compute roof: only kernel-level gains remain"


def run(csv: bool = True, path: str = "experiments/dryrun.jsonl",
        variants: bool = True):
    rows = [a for a in (analyze_record(r) for r in load(path)) if a]
    if not variants:
        rows = [r for r in rows if r["variant"] == "baseline"]
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"], r["variant"]))
    if csv:
        for r in rows:
            v = "" if r["variant"] == "baseline" else f"[{r['variant']}]"
            print(f"roofline,{r['arch']},{r['shape']},{r['mesh']}{v},"
                  f"t_comp={r['t_compute_s']:.4g},t_mem={r['t_memory_s']:.4g},"
                  f"t_coll={r['t_collective_s']:.4g},dom={r['dominant']},"
                  f"useful={r['useful_ratio']:.3f},"
                  f"roofline_frac={r['roofline_frac']:.3f}")
    return rows


def markdown_table(rows: list) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                 f"{r['t_compute_s']:.4g} | {r['t_memory_s']:.4g} | "
                 f"{r['t_collective_s']:.4g} | {r['dominant']} | "
                 f"{r['useful_ratio']:.3f} | {r['roofline_frac']:.3f} |\n")
    return hdr + body


if __name__ == "__main__":
    run()
