# One function per paper table/figure. Prints ``name,...,derived`` CSV.
"""Benchmark entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

  fig4_efficiency  — parallel efficiency vs workers x eval time (Fig. 4)
  fig5_*           — horizontal vs vertical HVDC scaling (Fig. 5)
  fig6_metaga      — meta-GA hyperparameter evolution (Fig. 6)
  broker/operator  — framework overhead microbench (Tab. 1 / §3 claims)
  roofline         — three-term roofline per dry-run cell (EXPERIMENTS.md)

Pass --quick for the fast subset (CI); --only NAME to run one section.
--json PATH dumps every section's rows machine-readably (the default
``BENCH_obs.json`` feeds dashboards and regression diffing — notably
the ``mq_dispatch_metrics_{off,on}`` observability-overhead pair and
the ``mq_autoscale_{depth,cost}_signal`` shoot-out).
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _jsonable(value):
    """Best-effort conversion of a benchmark row value (floats, numpy
    scalars, nested tuples) into plain JSON types."""
    if isinstance(value, (list, tuple)):
        return [_jsonable(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _jsonable(v) for k, v in value.items()}
    if isinstance(value, (str, int, bool)) or value is None:
        return value
    try:
        return float(value)
    except (TypeError, ValueError):
        return str(value)


def write_bench_json(path: str, sections: dict) -> None:
    """Dump every section's rows as ``{section: [[name, value], ...]}``
    — the machine-readable mirror of the CSV lines printed above."""
    with open(path, "w") as f:
        json.dump({k: _jsonable(v) for k, v in sections.items()},
                  f, indent=2, sort_keys=True)
        f.write("\n")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default="BENCH_obs.json", metavar="PATH",
                    help="write all section rows machine-readably "
                         "(empty string disables)")
    args = ap.parse_args(argv)

    sections = {}

    def want(name):
        return args.only is None or args.only == name

    t_all = time.perf_counter()

    if want("broker_overhead"):
        from benchmarks import broker_overhead
        print("# --- framework overhead (paper §3 / Tab. 1) ---")
        sections["broker_overhead"] = broker_overhead.run()

    if want("efficiency"):
        from benchmarks import efficiency
        print("# --- Fig. 4: parallel efficiency ---")
        sections["efficiency"] = efficiency.run()

    if want("hvdc_scaling"):
        from benchmarks import hvdc_scaling
        print("# --- Fig. 5: horizontal vs vertical HVDC ---")
        sections["hvdc_scaling"] = hvdc_scaling.run(
            grid_buses=30 if args.quick else 40,
            epochs=2 if args.quick else 4)

    if want("meta_ga"):
        from benchmarks import meta_ga
        print("# --- Fig. 6: meta-GA hyperparameters ---")
        sections["meta_ga"] = meta_ga.run(
            epochs=1 if args.quick else 2,
            pop=6 if args.quick else 8,
            inner_generations=4 if args.quick else 6)

    if want("roofline"):
        from benchmarks import roofline
        print("# --- roofline terms from the dry-run ---")
        sections["roofline"] = roofline.run()

    if args.json:
        write_bench_json(args.json, sections)
        print(f"# wrote {args.json}")
    print(f"# total {time.perf_counter() - t_all:.1f}s")


if __name__ == "__main__":
    main()
