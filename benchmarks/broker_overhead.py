"""Framework-overhead microbenchmarks (paper §3 / Tab. 1 claims).

Times the per-generation cost of each framework stage — selection+variation
(fused kernel vs unfused), NSGA-II survivor sort, broker dispatch on/off,
migration — against the pure fitness evaluation, plus the straggler-backup
variant, the decoupled host-pool path (unlearned vs learned EMA cost
model on a heterogeneous simulator), and the batch-queue (mock SLURM)
spool overhead. Supports the "negligible overhead" claim quantitatively.

Message-queue rows: ``batchq_tiny_chunks`` vs ``mq_tiny_chunks`` measures
startup amortization on a many-tiny-chunks workload (fresh numpy
interpreter per array task vs a persistent worker fleet; ~140x on a cold
spawn), and ``ema_first_update_{batchq,mq}`` measures cost-model
convergence WITHIN one generation — how far into a skewed batch the first
``CostEMA`` observation lands (batch-end collection ≈ the full makespan;
the streaming queue ≈ the fastest chunk).

Multi-tenant rows: ``mq_dedicated_fleets`` vs ``mq_shared_fleet`` runs
two concurrent skewed GA evaluations (one heavy, one light) on two
dedicated half-size fleets vs ONE shared run-scoped fleet — cross-run
work stealing lets the light run's idle workers drain the heavy queue,
pulling the combined makespan toward total_work/W instead of
heavy_work/(W/2). ``mq_fixed_min_fleet`` vs ``mq_autoscale_ramp`` puts a
burst of work on a 1-worker floor: the ``FleetAutoscaler`` sees the
queue depth, ramps the fleet to max_workers, and drains back to the
floor afterwards.

Transport rows (file broker vs socket broker, the same queue contract
over both): ``file_broker_claims_hb`` vs ``socket_broker_claims_hb``
hammers the bare worker protocol — claim, lease, a burst of heartbeats,
release, no fitness evaluation at all — with a high simulated worker
count (32 concurrent protocol loops), isolating pure transport cost:
directory scans + atomic renames + mtime touches on the file broker vs
length-prefixed RPC frames over persistent TCP connections into one
asyncio event loop on the socket broker. ``file_broker_result_latency``
vs ``socket_broker_result_latency`` times one full task round trip
(enqueue -> claim -> lease -> publish -> fetched), median of 30.

``mq_dispatch_sanitizer_absent`` vs ``mq_dispatch_sanitizer_loaded``
pins the thread sanitizer's zero-cost-when-disabled seam: importing
``repro.analysis.sanitize`` must leave the threading factories stock
and the measured mq dispatch cost unchanged — instrumentation exists
only inside an explicit ``instrumented()`` context.

Observability rows: ``mq_dispatch_metrics_off`` vs
``mq_dispatch_metrics_on`` pins the metrics bus's own
zero-cost-when-disabled seam (null registry vs a live
``MetricsRegistry`` + JSONL event log; target <5% overhead on the
tiny-chunks workload), and ``mq_autoscale_depth_signal`` vs
``mq_autoscale_cost_signal`` replays a skewed-cost burst under both
autoscaler signals — the cost signal reads the CostEMA-derived
per-task seconds off the metrics bus, predicts the outstanding work,
and out-provisions the depth heuristic on slow-task backlogs.
"""
from __future__ import annotations

import shutil
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GAConfig
from repro.core.broker import Broker, CostEMA, HostPoolBackend
from repro.core.engine import GAEngine
from repro.core.island import (evaluate_population, make_epoch_step,
                               make_generation_step)
from repro.core.population import init_population
from repro.fitness import delay_proxy, sphere
from repro.fitness import hostsim


def _time(f, *args, reps=5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6      # us


def run(csv: bool = True):
    rows = []
    cfg_base = dict(num_genes=18, pop_per_island=64, num_islands=4,
                    generations_per_epoch=1, num_epochs=1,
                    lower=-1.0, upper=1.0, seed=0)

    for fused in (False, True):
        cfg = GAConfig(fused_operators=fused, **cfg_base)
        broker = Broker(sphere)
        gen = jax.jit(lambda p, c=cfg, b=broker:
                      make_generation_step(c, b)(p, None))
        pop = init_population(cfg, jax.random.PRNGKey(0))
        pop = evaluate_population(cfg, broker, pop)
        us = _time(gen, pop)
        name = "generation_fused" if fused else "generation_unfused"
        rows.append((name, us))
        if csv:
            print(f"{name},{us:.0f},us_per_generation")

    # dispatch overhead: broker on/off with identical fitness
    fn = delay_proxy(sphere, flop_iters=5_000)
    cfg = GAConfig(fused_operators=False, **cfg_base)
    for with_cost in (False, True):
        cost_fn = (lambda g: jnp.sum(jnp.abs(g), -1)) if with_cost else None
        broker = Broker(fn, cost_fn=cost_fn, num_workers=16)
        gen = jax.jit(lambda p, c=cfg, b=broker:
                      make_generation_step(c, b)(p, None))
        pop = init_population(cfg, jax.random.PRNGKey(0))
        pop = evaluate_population(cfg, broker, pop)
        us = _time(gen, pop)
        name = "broker_balanced" if with_cost else "broker_identity"
        rows.append((name, us))
        if csv:
            print(f"{name},{us:.0f},us_per_generation")

    # total dispatch: N % W != 0 (padded balanced path — historically a
    # silent identity fallback; now pads 256 -> 264 over 24 lanes)
    broker = Broker(fn, cost_fn=lambda g: jnp.sum(jnp.abs(g), -1),
                    num_workers=24)
    gen = jax.jit(lambda p, c=cfg, b=broker:
                  make_generation_step(c, b)(p, None))
    pop = init_population(cfg, jax.random.PRNGKey(0))
    pop = evaluate_population(cfg, broker, pop)
    us = _time(gen, pop)
    rows.append(("broker_balanced_padded", us))
    if csv:
        print(f"broker_balanced_padded,{us:.0f},us_per_generation")

    # migration epoch vs generations-only
    cfg = GAConfig(fused_operators=False, **{**cfg_base,
                                             "generations_per_epoch": 5})
    broker = Broker(sphere)
    epoch = jax.jit(make_epoch_step(cfg, broker))
    pop = init_population(cfg, jax.random.PRNGKey(0))
    pop = evaluate_population(cfg, broker, pop)
    us = _time(lambda p: epoch(p)[0], pop)
    rows.append(("epoch_5gen_plus_migration", us))
    if csv:
        print(f"epoch_5gen_plus_migration,{us:.0f},us_per_epoch")

    # learned cost model on a decoupled host pool: the hot individuals
    # are exactly one lane of the *uniform* balanced assignment, so the
    # unlearned round 1 serializes the full hot makespan on one worker;
    # after the EMA charges those slots, the balanced permutation spreads
    # them and the measured makespan drops ~w-fold
    import functools
    from repro.core.broker import balanced_permutation as _bp
    n, w = 64, 8
    perm0 = np.asarray(_bp(jnp.ones(n), w))
    hot = np.zeros(n, bool)
    hot[perm0[:n // w]] = True
    het_fn = functools.partial(hostsim.delay_sphere, slow_s=0.002)
    het_g = np.random.default_rng(0).uniform(-1, 1, (n, 6)).astype(
        np.float32)
    het_g[:, 0] = np.where(hot, 1.0, -1.0)
    het_gj = jnp.asarray(het_g)
    ema = CostEMA(alpha=0.6)
    backend = HostPoolBackend(het_fn, num_workers=w)
    broker = Broker(cost_fn=ema, num_workers=w, backend=backend)
    ev = jax.jit(lambda g, b=broker: b.evaluate(g)[0])
    # compile on an all-fast batch so round 1 measures unlearned
    # dispatch, not XLA compilation
    g_fast = het_g.copy()
    g_fast[:, 0] = -1.0
    jax.block_until_ready(ev(jnp.asarray(g_fast)))
    ema.reset()                                 # drop warm-up estimates
    t0 = time.perf_counter()
    jax.block_until_ready(ev(het_gj))
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("hostpool_ema_round1", us))
    if csv:
        print(f"hostpool_ema_round1,{us:.0f},us_per_evaluate")
    us = _time(ev, het_gj, reps=3)              # steady state: learned
    backend.close()
    rows.append(("hostpool_ema_learned", us))
    if csv:
        print(f"hostpool_ema_learned,{us:.0f},us_per_evaluate")

    # batch-queue dispatch overhead: spool write + mock scheduler + result
    # polling per evaluate (thread-mode workers, trivial fitness)
    from repro.runtime.batchq import LocalMockScheduler, SlurmArrayBackend
    backend = SlurmArrayBackend(fn_spec="repro.fitness.hostsim:sphere",
                                num_workers=8,
                                scheduler=LocalMockScheduler(mode="thread"),
                                chunk_timeout_s=60, poll_interval_s=0.002)
    broker = Broker(cost_fn=lambda g: jnp.sum(jnp.abs(g), -1) + 0.1,
                    num_workers=8, backend=backend)
    ev = jax.jit(lambda g, b=broker: b.evaluate(g)[0])
    jax.block_until_ready(ev(het_gj))
    us = _time(ev, het_gj, reps=3)
    backend.close()
    rows.append(("slurm_mock_spool", us))
    if csv:
        print(f"slurm_mock_spool,{us:.0f},us_per_evaluate")

    # equal vs cost-sized chunking on a skewed simulator: 4 hot genomes
    # (280ms each) among 60 cheap ones (20ms each), 8 array tasks. Equal
    # counts force 7 cheap riders into every hot chunk (makespan
    # 260+8*20 = 420ms); cost-sized chunking isolates each hot genome in
    # a 1-item chunk and spreads the cheap ones ~15 per task (makespan
    # ~300ms) — array tasks finish together. Static cost model, measured
    # under jit. (Sleeps are sized so the makespan delta dominates the
    # ~100ms fixed spool overhead of the mock scheduler.)
    skew_n, skew_w = 64, 8
    skew_g = np.random.default_rng(1).uniform(-1, 1, (skew_n, 6)).astype(
        np.float32)
    skew_g[:, 0] = -1.0
    skew_g[:4, 0] = 1.0                          # 4 hot genomes
    skew_gj = jnp.asarray(skew_g)
    skew_fn = functools.partial(hostsim.delay_sphere, slow_s=0.260,
                                base_s=0.020)
    skew_cost = lambda g: jnp.where(g[:, 0] > 0, 14.0, 1.0)  # 280 vs 20ms
    for sizing in ("equal", "cost"):
        backend = SlurmArrayBackend(
            skew_fn, num_workers=skew_w,
            scheduler=LocalMockScheduler(mode="thread"),
            chunk_timeout_s=60, poll_interval_s=0.002,
            chunk_sizing=sizing)
        broker = Broker(cost_fn=skew_cost, num_workers=skew_w,
                        backend=backend)
        ev = jax.jit(lambda g, b=broker: b.evaluate(g)[0])
        jax.block_until_ready(ev(skew_gj))
        us = _time(ev, skew_gj, reps=3)
        backend.close()
        rows.append((f"batchq_{sizing}_chunks", us))
        if csv:
            print(f"batchq_{sizing}_chunks,{us:.0f},us_per_evaluate")

    # persistent-worker message queue vs batch spool on a MANY-TINY-CHUNKS
    # workload: 24 trivial genomes over 6 chunks. The batch backend spawns
    # a fresh numpy interpreter per chunk per evaluate (~0.8s startup each,
    # bounded by core count); the mq fleet pays startup once at launch and
    # each evaluate is only queue-file traffic — the startup-amortization
    # claim, measured
    from repro.runtime.mq import LocalWorkerPool, QueueBackend
    tiny_w = 6
    tiny_g = jnp.asarray(np.random.default_rng(2).uniform(
        -1, 1, (24, 6)).astype(np.float32))
    backend = SlurmArrayBackend(
        fn_spec="repro.fitness.hostsim:sphere", num_workers=tiny_w,
        scheduler=LocalMockScheduler(mode="subprocess"),
        chunk_timeout_s=300, poll_interval_s=0.01)
    ev = jax.jit(lambda g, b=Broker(backend=backend): b.evaluate(g)[0])
    jax.block_until_ready(ev(tiny_g))
    us = _time(ev, tiny_g, reps=2)
    backend.close()
    rows.append(("batchq_tiny_chunks", us))
    if csv:
        print(f"batchq_tiny_chunks,{us:.0f},us_per_evaluate")
    backend = QueueBackend(
        fn_spec="repro.fitness.hostsim:sphere", num_workers=tiny_w,
        worker_pool=LocalWorkerPool(num_workers=tiny_w, mode="subprocess"),
        chunk_timeout_s=300, poll_interval_s=0.002)
    ev = jax.jit(lambda g, b=Broker(backend=backend): b.evaluate(g)[0])
    jax.block_until_ready(ev(tiny_g))           # includes fleet spin-up
    us = _time(ev, tiny_g, reps=2)
    backend.close()
    rows.append(("mq_tiny_chunks", us))
    if csv:
        print(f"mq_tiny_chunks,{us:.0f},us_per_evaluate")

    # sanitizer zero-cost when disabled: merely importing the thread
    # sanitizer (repro.analysis.sanitize) must leave the dispatch path
    # untouched — stock threading factories, no tracing branch anywhere
    # in runtime/. Identical mq dispatch measured before and after the
    # import; any delta between these two rows is timer noise.
    san_w = 4
    san_g = jnp.asarray(np.random.default_rng(4).uniform(
        -1, 1, (32, 6)).astype(np.float32))

    def _mq_dispatch_us():
        backend = QueueBackend(
            hostsim.sphere, num_workers=san_w,
            worker_pool=LocalWorkerPool(num_workers=san_w, mode="thread",
                                        fn=hostsim.sphere, poll_s=0.002),
            chunk_timeout_s=60, poll_interval_s=0.002)
        ev = jax.jit(lambda g, b=Broker(backend=backend): b.evaluate(g)[0])
        jax.block_until_ready(ev(san_g))
        us = _time(ev, san_g, reps=3)
        backend.close()
        return us

    lock_before = threading.Lock
    us = _mq_dispatch_us()
    rows.append(("mq_dispatch_sanitizer_absent", us))
    if csv:
        print(f"mq_dispatch_sanitizer_absent,{us:.0f},us_per_evaluate")
    import repro.analysis.sanitize              # noqa: F401 — loaded, NOT enabled
    assert threading.Lock is lock_before, \
        "importing the sanitizer must not patch threading"
    us = _mq_dispatch_us()
    rows.append(("mq_dispatch_sanitizer_loaded", us))
    if csv:
        print(f"mq_dispatch_sanitizer_loaded,{us:.0f},us_per_evaluate")

    # observability plane, same zero-cost contract: identical mq
    # dispatch with the metrics bus OFF (the null-registry seam — one
    # attribute check per emission site) vs ON (a live MetricsRegistry
    # + JSONL event log installed through repro.runtime.metrics).
    # Target: <5% instrumented overhead on this tiny-chunks workload
    import os as _os

    from repro.obs import EventLog, MetricsRegistry
    from repro.runtime import metrics as runtime_metrics
    us_off = _mq_dispatch_us()
    rows.append(("mq_dispatch_metrics_off", us_off))
    if csv:
        print(f"mq_dispatch_metrics_off,{us_off:.0f},us_per_evaluate")
    obs_dir = tempfile.mkdtemp(prefix="chambga-obsbench-")
    obs_log = EventLog(_os.path.join(obs_dir, "events.jsonl"))
    runtime_metrics.set_registry(MetricsRegistry(events=obs_log))
    try:
        us_on = _mq_dispatch_us()
    finally:
        runtime_metrics.set_registry(None)
        obs_log.close()
        shutil.rmtree(obs_dir, ignore_errors=True)
    rows.append(("mq_dispatch_metrics_on", us_on))
    if csv:
        print(f"mq_dispatch_metrics_on,{us_on:.0f},us_per_evaluate_"
              f"{(us_on / us_off - 1) * 100:+.1f}pct_vs_off")

    # cost convergence WITHIN a generation: time from batch start to the
    # FIRST CostEMA observation on a skewed simulator. The batch backend
    # observes at collect time (≈ the full makespan); the mq backend
    # streams each chunk's duration as it lands (≈ the fastest chunk) —
    # the next dispatch decision can be made that much earlier
    class _FirstObsEMA(CostEMA):
        def __init__(self):
            super().__init__(alpha=0.5)
            self.t_first = None

        def observe(self, perm, chunk_sizes, durations):
            if self.t_first is None:
                self.t_first = time.perf_counter()
            super().observe(perm, chunk_sizes, durations)

    ema_n, ema_w = 32, 4
    ema_g = np.random.default_rng(3).uniform(
        -1, 1, (ema_n, 6)).astype(np.float32)
    ema_g[:, 0] = -1.0
    # the hot genomes fill exactly ONE lane of the uniform (unlearned)
    # balanced assignment: that chunk serializes the whole hot makespan
    # while the other chunks land almost immediately — the gap between
    # "first chunk done" and "batch done" that streaming exploits
    ema_perm0 = np.asarray(_bp(jnp.ones(ema_n), ema_w))
    ema_g[ema_perm0[:ema_n // ema_w], 0] = 1.0
    ema_gj = jnp.asarray(ema_g)
    ema_fn = functools.partial(hostsim.delay_sphere, slow_s=0.030)
    for name, make in (
            ("batchq", lambda ema: SlurmArrayBackend(
                ema_fn, num_workers=ema_w,
                scheduler=LocalMockScheduler(mode="thread"),
                chunk_timeout_s=60, poll_interval_s=0.002, cost_ema=ema)),
            ("mq", lambda ema: QueueBackend(
                ema_fn, num_workers=ema_w,
                worker_pool=LocalWorkerPool(num_workers=ema_w,
                                            mode="thread", fn=ema_fn,
                                            poll_s=0.002),
                chunk_timeout_s=60, poll_interval_s=0.002, cost_ema=ema))):
        ema = _FirstObsEMA()
        backend = make(ema)
        broker = Broker(cost_fn=ema, num_workers=ema_w, backend=backend)
        ev = jax.jit(lambda g, b=broker: b.evaluate(g)[0])
        jax.block_until_ready(ev(jnp.asarray(
            np.full_like(ema_g, -1.0))))        # compile on an all-fast batch
        ema.reset()
        ema.t_first = None
        t0 = time.perf_counter()
        jax.block_until_ready(ev(ema_gj))
        t_batch = time.perf_counter() - t0
        us = (ema.t_first - t0) * 1e6
        backend.close()
        rows.append((f"ema_first_update_{name}", us))
        if csv:
            print(f"ema_first_update_{name},{us:.0f},us_into_a_"
                  f"{t_batch * 1e3:.0f}ms_batch")

    # multi-tenant fleet sharing: two concurrent runs — one heavy (every
    # genome sleeps 30ms), one light (2ms) — on (a) two DEDICATED fleets
    # of 2 workers each vs (b) ONE shared 4-worker fleet with run-scoped
    # queues. Cross-run work stealing lets the light run's idle workers
    # drain the heavy queue once their own is empty: combined makespan
    # drops toward total_work/4 instead of heavy_work/2
    from repro.core.broker import Broker as _Broker
    from repro.runtime.mq import FleetAutoscaler
    heavy_fn = functools.partial(hostsim.delay_sphere, slow_s=0.030)
    light_fn = functools.partial(hostsim.delay_sphere, base_s=0.002)
    g_heavy = np.random.default_rng(5).uniform(-1, 1, (24, 4)).astype(
        np.float32)
    g_heavy[:, 0] = 1.0
    g_light = np.random.default_rng(6).uniform(-1, 1, (24, 4)).astype(
        np.float32)
    g_light[:, 0] = -1.0

    def _two_run_wall(shared: bool) -> float:
        dirs, pools, backends = [], [], []
        mt_fast = dict(chunk_timeout_s=300, poll_interval_s=0.002,
                       num_workers=8)           # 8 chunks > any fleet
        try:
            if shared:
                d = tempfile.mkdtemp(prefix="chambga-mt-")
                dirs.append(d)
                pools.append(LocalWorkerPool(
                    num_workers=4, mode="thread", mq_dir=d,
                    lease_s=30.0, poll_s=0.002).start())
                b_h = QueueBackend(heavy_fn, run_id="heavy", mq_dir=d,
                                   **mt_fast)
                b_l = QueueBackend(light_fn, run_id="light", mq_dir=d,
                                   **mt_fast)
            else:
                b_h = b_l = None
                for tag, fn in (("heavy", heavy_fn), ("light", light_fn)):
                    d = tempfile.mkdtemp(prefix="chambga-mt-")
                    dirs.append(d)
                    b = QueueBackend(
                        fn, run_id=tag, mq_dir=d,
                        worker_pool=LocalWorkerPool(
                            num_workers=2, mode="thread",
                            lease_s=30.0, poll_s=0.002),
                        **mt_fast)
                    b_h, b_l = (b, b_l) if tag == "heavy" else (b_h, b)
            backends += [b_h, b_l]
            outs = {}
            threads = [
                threading.Thread(target=lambda: outs.update(
                    h=b_h._host_eval(g_heavy)), daemon=True),
                threading.Thread(target=lambda: outs.update(
                    l=b_l._host_eval(g_light)), daemon=True)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            return time.perf_counter() - t0
        finally:
            for b in backends:
                b.close()
            for p in pools:
                p.stop()
            for d in dirs:
                shutil.rmtree(d, ignore_errors=True)

    for shared in (False, True):
        wall = min(_two_run_wall(shared) for _ in range(2))
        name = "mq_shared_fleet" if shared else "mq_dedicated_fleets"
        rows.append((name, wall * 1e6))
        if csv:
            print(f"{name},{wall * 1e6:.0f},us_both_runs_makespan")

    # queue-depth autoscaling: the same heavy burst on a fleet FLOORED at
    # one worker. Fixed: serial makespan. Autoscaled: the controller sees
    # the depth, ramps to max_workers through the pool's incremental
    # submit, and drains back to the floor via poison STOP tickets
    for autoscaled in (False, True):
        d = tempfile.mkdtemp(prefix="chambga-ramp-")
        pool = LocalWorkerPool(num_workers=1, mode="thread", mq_dir=d,
                               lease_s=30.0, poll_s=0.002)
        scaler = (FleetAutoscaler(pool, min_workers=1, max_workers=4,
                                  interval_s=0.02, cooldown_s=0.04)
                  if autoscaled else None)
        backend = QueueBackend(heavy_fn, run_id="ramp", mq_dir=d,
                               worker_pool=pool, autoscaler=scaler,
                               chunk_timeout_s=300, poll_interval_s=0.002,
                               num_workers=8)
        ramp_broker = _Broker(backend=backend)
        t0 = time.perf_counter()
        backend._host_eval(g_heavy)
        wall = time.perf_counter() - t0
        peak = scaler.stats_snapshot()["peak_workers"] if scaler else 1
        bstats = ramp_broker.backend_stats()
        backend.close()
        shutil.rmtree(d, ignore_errors=True)
        name = "mq_autoscale_ramp" if autoscaled else "mq_fixed_min_fleet"
        rows.append((name, wall * 1e6))
        if csv:
            print(f"{name},{wall * 1e6:.0f},us_per_evaluate_peak_{peak}"
                  f"_workers_jobs_{bstats.get('jobs', 0)}")

    # autoscaler signal shoot-out on a SKEWED-COST burst: 8 chunks of
    # ~90ms each from a 1-worker floor. The depth signal provisions
    # ceil(8 / backlog_per_worker=3) = 3 workers — blind to how slow
    # each task is. The cost signal multiplies the measured per-task
    # CostEMA (published by the backend into the metrics bus) by the
    # ready depth: 8 x 90ms outstanding against an 80ms horizon wants
    # far more than 3, clamps to max_workers=6, and drains the burst
    # in ~2 waves instead of ~3
    for sig in ("depth", "cost"):
        reg = MetricsRegistry()
        runtime_metrics.set_registry(reg)
        d = tempfile.mkdtemp(prefix="chambga-sig-")
        pool = LocalWorkerPool(num_workers=1, mode="thread", mq_dir=d,
                               lease_s=30.0, poll_s=0.002)
        scaler = FleetAutoscaler(pool, min_workers=1, max_workers=6,
                                 interval_s=0.02, cooldown_s=0.04,
                                 backlog_per_worker=3.0, signal=sig,
                                 metrics=reg, cost_horizon_s=0.08,
                                 default_cost_s=0.09)
        backend = QueueBackend(heavy_fn, run_id=f"sig-{sig}", mq_dir=d,
                               worker_pool=pool, autoscaler=scaler,
                               chunk_timeout_s=300, poll_interval_s=0.002,
                               num_workers=8)
        t0 = time.perf_counter()
        backend._host_eval(g_heavy)
        wall = time.perf_counter() - t0
        peak = scaler.stats_snapshot()["peak_workers"]
        backend.close()
        runtime_metrics.set_registry(None)
        shutil.rmtree(d, ignore_errors=True)
        name = f"mq_autoscale_{sig}_signal"
        rows.append((name, wall * 1e6))
        if csv:
            print(f"{name},{wall * 1e6:.0f},us_per_evaluate_peak_{peak}"
                  f"_workers")

    # file broker vs socket broker: the SAME queue contract over its two
    # transports at a high simulated worker count. 32 "workers" each run
    # the bare protocol — claim, lease, 64 heartbeats, release — with no
    # fitness evaluation, so the throughput rows isolate transport cost
    # alone; the latency rows time one full task round trip end to end
    import os

    from repro.runtime import mq as mq_proto
    from repro.runtime.fsatomic import atomic_savez
    from repro.runtime.netbroker import BrokerClient, BrokerServer

    nb_w, nb_hb, nb_reps = 32, 64, 30
    nb_g = np.random.default_rng(7).uniform(-1, 1, (8, 4)).astype(
        np.float32)
    nb_fit = np.asarray(hostsim.sphere(nb_g), np.float32).reshape(
        len(nb_g), -1)
    nb_spec = "repro.fitness.hostsim:sphere"

    def _nb_hammer(enqueue, workers):
        """claims+heartbeats/sec over nb_w concurrent protocol loops."""
        for i in range(nb_w):
            enqueue(mq_proto.task_name("a", 0, i, 0, 0))
        go = threading.Event()
        threads = [threading.Thread(target=w, args=(go,), daemon=True)
                   for w in workers]
        for t in threads:
            t.start()
        t0 = time.perf_counter()
        go.set()
        for t in threads:
            t.join()
        return nb_w * (1 + nb_hb) / (time.perf_counter() - t0)

    # -- file transport: protocol functions against a shared directory
    nb_dir = tempfile.mkdtemp(prefix="chambga-nbbench-")
    mq_proto.make_broker_dirs(nb_dir)
    mq_proto.register_run(nb_dir, "a", fn_spec=nb_spec)

    def _file_enqueue(name):
        atomic_savez(os.path.join(nb_dir, mq_proto.TASKS_DIR, name),
                     genomes=nb_g)

    def _file_worker(go):
        go.wait()
        name = None
        while name is None:
            name = mq_proto.claim_next(nb_dir)
        lease = mq_proto.write_lease(nb_dir, name)
        for _ in range(nb_hb):
            os.utime(lease, None)
        mq_proto.release_claim(nb_dir, name)

    rate = _nb_hammer(_file_enqueue, [_file_worker] * nb_w)
    rows.append(("file_broker_claims_hb", rate))
    if csv:
        print(f"file_broker_claims_hb,{rate:.0f},claims_plus_heartbeats_"
              f"per_sec_{nb_w}_workers")
    lats = []
    for i in range(nb_reps):
        name = mq_proto.task_name("a", 1, i, 0, 0)
        t0 = time.perf_counter()
        _file_enqueue(name)
        got = mq_proto.claim_next(nb_dir)
        mq_proto.write_lease(nb_dir, got)
        mq_proto.publish_result(nb_dir, got, nb_fit, 0.01)
        with np.load(mq_proto.mq_result_path(nb_dir, got)) as z:
            z["fitness"]
        lats.append(time.perf_counter() - t0)
        mq_proto.release_claim(nb_dir, got)
        os.remove(mq_proto.mq_result_path(nb_dir, got))
    us = float(np.median(lats)) * 1e6
    rows.append(("file_broker_result_latency", us))
    if csv:
        print(f"file_broker_result_latency,{us:.0f},"
              f"us_enqueue_to_fetched_median")
    shutil.rmtree(nb_dir, ignore_errors=True)

    # -- socket transport: the same protocol as RPC frames, one
    #    persistent connection per simulated worker
    with BrokerServer() as nb_server:
        nb_mgr = BrokerClient(nb_server.addr)
        nb_mgr.register_run("a", fn_spec=nb_spec)
        nb_clients = [BrokerClient(nb_server.addr) for _ in range(nb_w)]

        def _net_worker(c):
            def w(go):
                go.wait()
                name = None
                while name is None:
                    reply, _ = c.claim()
                    name = reply["name"]
                c.lease(name)
                for _ in range(nb_hb):
                    c.heartbeat(name)
                c.release(name)
            return w

        rate = _nb_hammer(lambda name: nb_mgr.enqueue(name, nb_g),
                          [_net_worker(c) for c in nb_clients])
        rows.append(("socket_broker_claims_hb", rate))
        if csv:
            print(f"socket_broker_claims_hb,{rate:.0f},"
                  f"claims_plus_heartbeats_per_sec_{nb_w}_workers")
        lats = []
        for i in range(nb_reps):
            name = mq_proto.task_name("a", 1, i, 0, 0)
            t0 = time.perf_counter()
            nb_mgr.enqueue(name, nb_g)
            reply, _ = nb_mgr.claim()
            got = reply["name"]
            nb_mgr.lease(got)
            nb_mgr.result(got, nb_fit, 0.01)
            assert nb_mgr.result_fetch(got) is not None
            lats.append(time.perf_counter() - t0)
            nb_mgr.release(got)
        us = float(np.median(lats)) * 1e6
        rows.append(("socket_broker_result_latency", us))
        if csv:
            print(f"socket_broker_result_latency,{us:.0f},"
                  f"us_enqueue_to_fetched_median")
        for c in nb_clients:
            c.close()
        nb_mgr.close()

    # engine loop: synchronous metric reads every epoch vs the pipelined
    # (async D2H + deferred device_get) path — async must be no slower
    cfg = GAConfig(fused_operators=False,
                   **{**cfg_base, "generations_per_epoch": 5})
    n_epochs = 20
    for name, kw in (("engine_sync", dict(sync_every=1, pipeline_depth=0)),
                     ("engine_pipelined", dict(sync_every=4,
                                               pipeline_depth=2))):
        eng = GAEngine(cfg, delay_proxy(sphere, flop_iters=5_000), **kw)
        eng.run(eng.init(), epochs=1)           # warm up compile
        best_s = float("inf")
        for _ in range(3):                      # min-of-3: shed timer noise
            pop0 = eng.init()                   # init outside the clock
            t0 = time.perf_counter()
            eng.run(pop0, epochs=n_epochs)
            best_s = min(best_s, time.perf_counter() - t0)
        us = best_s / n_epochs * 1e6
        rows.append((name, us))
        if csv:
            print(f"{name},{us:.0f},us_per_epoch")
    return rows


if __name__ == "__main__":
    run()
