"""Paper Fig. 4: parallel efficiency vs worker count and evaluation time.

rho = s * P * M * N_E * I / (T * N_w)   (paper eq. 1)

On this CPU container we cannot spread workers over real chips, so the
measurement isolates exactly what the paper's benchmark isolates: the
*framework overhead* (selection, variation, survivor sort, broker dispatch,
migration, host round-trips) relative to pure fitness-evaluation time. The
per-individual evaluation cost `s` is a calibrated on-device FLOP loop
(fitness.delay_proxy), and N_w on one device is the number of parallel
evaluation lanes the SPMD program carries (vectorization width).

On a real pod, lanes map 1:1 to chips and the same harness measures the
paper's Fig. 4; the dry-run proves the program shards.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import GAConfig
from repro.core.broker import Broker
from repro.core.island import evaluate_population, make_epoch_step
from repro.core.population import init_population
from repro.fitness import delay_proxy, sphere


def measure_efficiency(*, workers: int, sleep_iters: int,
                       pop_per_island: int, islands: int,
                       generations: int, epochs: int,
                       seed: int = 0) -> float:
    """One Fig.-4 cell: returns rho = T_eval / T_epoch.

    T_eval  — wall time of the fitness evaluations alone (M*N_E broker
              evaluations of the full population), the paper's s*P*M*N_E*I
              numerator measured on this hardware instead of assumed.
    T_epoch — wall time of the full framework epochs (selection, variation,
              survivor sort, dispatch, migration + the same evaluations).
    rho <= 1 by construction; 1 - rho is the framework overhead fraction —
    exactly what the paper's Fig. 4 isolates with its sleep(s) loads.
    """
    cfg = GAConfig(num_genes=4, pop_per_island=pop_per_island,
                   num_islands=islands, generations_per_epoch=generations,
                   num_epochs=epochs, lower=-1.0, upper=1.0,
                   fused_operators=False, seed=seed)
    fn = delay_proxy(sphere, flop_iters=sleep_iters)
    broker = Broker(fn, num_workers=workers)
    epoch = jax.jit(make_epoch_step(cfg, broker))

    # T_eval in the SAME structural form as the epoch (a scan of M
    # evaluations inside one jit) so dispatch/loop overheads cancel and the
    # ratio isolates the framework's GA-ops overhead.
    flat = cfg.global_pop

    def eval_epoch(genomes):
        def body(c, _):
            f, _ = broker.evaluate(c.reshape(flat, cfg.num_genes))
            # thread a data dependency so the scan isn't collapsed
            c = c + 0.0 * f.reshape(cfg.num_islands, cfg.pop_per_island,
                                    -1)[..., :1] * 0.0
            return c, None
        return jax.lax.scan(body, genomes, None,
                            length=generations)[0]

    eval_jit = jax.jit(eval_epoch)

    pop = init_population(cfg, jax.random.PRNGKey(seed))
    pop = evaluate_population(cfg, broker, pop)
    jax.block_until_ready(epoch(pop)[0])
    jax.block_until_ready(eval_jit(pop.genomes))

    t0 = time.perf_counter()
    out = None
    for _ in range(epochs):
        out = eval_jit(pop.genomes)
    jax.block_until_ready(out)
    t_eval = time.perf_counter() - t0

    p2 = pop
    t0 = time.perf_counter()
    for _ in range(epochs):
        p2, _ = epoch(p2)
    jax.block_until_ready(p2)
    t_epoch = time.perf_counter() - t0

    return float(t_eval / t_epoch)


def run(csv: bool = True):
    rows = []
    for workers, iters in [(1, 20_000), (4, 20_000), (16, 20_000),
                           (16, 100_000), (16, 400_000), (64, 20_000)]:
        rho = measure_efficiency(workers=workers, sleep_iters=iters,
                                 pop_per_island=32, islands=4,
                                 generations=3, epochs=2)
        rows.append(("fig4_efficiency", workers, iters, round(rho, 4)))
        if csv:
            print(f"fig4_efficiency,workers={workers},iters={iters},"
                  f"rho={rho:.4f}")
    return rows


if __name__ == "__main__":
    run()
