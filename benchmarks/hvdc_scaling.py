"""Paper Fig. 5: horizontal vs vertical scaling on the HVDC dispatch
problem at equal total compute.

(a) horizontal-priority: large population, 1-lane-per-evaluation
(b) vertical-priority: small population, contingency batch sharded wide

On this container both run at CPU scale (small grid, few contingencies);
the printed trajectories reproduce the paper's qualitative finding: both
make progress, horizontal completes more evaluations, vertical spends more
compute per individual — neither strictly dominates (§4.2.1).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GAConfig
from repro.core.engine import GAEngine
from repro.fitness.powerflow import HVDCDispatchFitness
from repro.powerflow.grid import make_synthetic_grid


def run(csv: bool = True, *, grid_buses: int = 40, epochs: int = 4):
    grid = make_synthetic_grid(n_bus=grid_buses,
                               n_line=int(grid_buses * 1.9),
                               n_gen=max(6, grid_buses // 4), n_hvdc=4,
                               seed=3)
    rows = []
    # paper Tab. 3 settings, scaled down
    settings = {
        # (a) horizontal: P=412-like (here 32/island), no contingencies/ind
        "horizontal": dict(pop=32, contingencies=0,
                           mutation_eta=34.6, crossover_eta=97.5,
                           migration=5),
        # (b) vertical: P=16-like (here 8/island), contingency-heavy eval
        "vertical": dict(pop=8, contingencies=12,
                         mutation_eta=90.2, crossover_eta=5.2,
                         migration=6),
    }
    for name, s in settings.items():
        fit = HVDCDispatchFitness(grid, contingencies=s["contingencies"],
                                  newton_iters=8)
        cfg = GAConfig(num_genes=grid.n_hvdc, pop_per_island=s["pop"],
                       num_islands=2, generations_per_epoch=s["migration"],
                       num_epochs=epochs, lower=-1.0, upper=1.0,
                       mutation_prob=0.7 if name == "horizontal" else 0.5,
                       mutation_eta=s["mutation_eta"],
                       crossover_prob=1.0, crossover_eta=s["crossover_eta"],
                       fused_operators=False, seed=1)
        eng = GAEngine(cfg, jax.jit(fit), cost_fn=fit.cost_model())
        t0 = time.perf_counter()
        pop, hist = eng.run()
        dt = time.perf_counter() - t0
        evals = float(jax.device_get(pop.evals))
        pf_solves = evals * (1 + s["contingencies"])
        best = hist[-1]["best"]
        rows.append((f"fig5_{name}", dt, best, evals, pf_solves))
        if csv:
            print(f"fig5_{name},t={dt:.1f}s,best={best:.3f},"
                  f"evals={evals:.0f},pf_solves={pf_solves:.0f}")
    return rows


if __name__ == "__main__":
    run()
